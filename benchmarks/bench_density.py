"""Thm 3.3: vertex-induced subgraph density vs batch size (nondecreasing)."""
from __future__ import annotations

from benchmarks.common import Csv, bench_graph
from repro.core.theory import measure_density_curve


def run(trials: int = 8) -> Csv:
    g = bench_graph()
    bs, density = measure_density_curve(
        g, [64, 128, 256, 512, 1024, 2048], trials=trials
    )
    csv = Csv(["batch_size", "density_E_per_V"])
    for b, d in zip(bs, density):
        csv.add(b, round(d, 4))
    return csv


if __name__ == "__main__":
    run().emit()
