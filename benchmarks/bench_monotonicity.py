"""Fig 3 / Fig 6: work per epoch vs batch size, per sampler.

Emits E[|S^L|] (concave, Thm 3.2) and E[|S^L|]/|S^0| (nonincreasing,
Thm 3.1) for NS / LABOR-0 / LABOR-* / RW on a power-law RMAT graph.
"""
from __future__ import annotations

from benchmarks.common import Csv, bench_graph
from repro.core.samplers import make_sampler
from repro.core.theory import measure_work_curve

BATCHES = [16, 32, 64, 128, 256, 512, 1024]
SAMPLERS = ["ns", "labor0", "labor*", "rw"]


def run(trials: int = 6) -> Csv:
    g = bench_graph()
    csv = Csv(["sampler", "batch_size", "E_SL", "work_per_seed"])
    for name in SAMPLERS:
        s = make_sampler(name, fanout=5, **({"num_walks": 8} if name == "rw" else {}))
        curve = measure_work_curve(
            g, s, BATCHES, num_layers=3, trials=trials, fanout_for_caps=5
        )
        for b, e, w in zip(curve.batch_sizes, curve.expected_sl, curve.work_per_seed):
            csv.add(name, b, round(e, 1), round(w, 3))
    return csv


if __name__ == "__main__":
    run().emit()
