"""Fig 4 / Fig 9: convergence parity.

(a) cooperative vs independent minibatching at equal global batch size,
(b) dependent minibatching across kappa — validation F1 must not degrade
for moderate kappa (paper: < 0.1% up to kappa=256; our small-scale proxy
checks the same ordering within noise).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv
from repro.data.synthetic import SyntheticGraphDataset
from repro.data import rmat_graph
from repro.models.gnn import GNNConfig
from repro.train.loop import TrainConfig, evaluate, train_gnn

STEPS = 60


def run() -> Csv:
    g = rmat_graph(scale=10, edge_factor=8, max_degree=32, seed=0)
    ds = SyntheticGraphDataset(g, feature_dim=32, num_classes=8, seed=0)
    cfg = GNNConfig(model="gcn", num_layers=2, in_dim=32, hidden_dim=64, num_classes=8)
    csv = Csv(["experiment", "setting", "final_loss", "val_f1"])

    for mode in ("independent", "cooperative"):
        tc = TrainConfig(mode=mode, num_pes=4, local_batch=32, num_steps=STEPS,
                         fanout=5, eval_every=0, seed=3)
        r = train_gnn(ds, cfg, tc)
        f1 = evaluate(ds, cfg, r.params, tc)
        csv.add("coop_vs_indep", mode, round(float(np.mean(r.losses[-8:])), 4),
                round(f1, 4))

    for kappa in (1, 16, 64, None):
        tc = TrainConfig(mode="cooperative", num_pes=2, local_batch=64,
                         num_steps=STEPS, fanout=5, kappa=kappa, eval_every=0,
                         seed=3)
        r = train_gnn(ds, cfg, tc)
        f1 = evaluate(ds, cfg, r.params, tc)
        csv.add("dependent_kappa", kappa if kappa else "inf",
                round(float(np.mean(r.losses[-8:])), 4), round(f1, 4))
    return csv


if __name__ == "__main__":
    run().emit()
