"""Gate a benchmark snapshot against a committed baseline.

    PYTHONPATH=src python -m benchmarks.compare_snapshots \
        benchmarks/baselines/BENCH_plan_build.json BENCH_plan_build.json \
        --metrics speedups --threshold 0.10

Reads the dict of numbers at ``--metrics`` (a dotted path) in both
files, intersects their keys, and exits non-zero if any current value
fell more than ``--threshold`` (fractional) below the baseline.  Higher
is assumed better (speedup ratios, hit rates); pass ``--lower-better``
for latency-style metrics where a *rise* is the regression.

Keys present on only one side are reported but never fail the gate —
baselines age as sweeps grow, and a new shape has nothing to regress
against.
"""
from __future__ import annotations

import argparse
import json
import sys


def _dig(payload: dict, path: str) -> dict:
    node = payload
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"no '{path}' in snapshot (missing '{part}')")
        node = node[part]
    if not isinstance(node, dict):
        raise KeyError(f"'{path}' is not a metrics dict")
    return {k: float(v) for k, v in node.items()
            if isinstance(v, (int, float))}


def compare(baseline: dict, current: dict, threshold: float,
            lower_better: bool = False):
    """-> (regressions, improvements, only_in_one) over intersecting keys."""
    regressions, improvements, skipped = [], [], []
    for key in sorted(set(baseline) | set(current)):
        if key not in baseline or key not in current:
            skipped.append(key)
            continue
        base, cur = baseline[key], current[key]
        if base == 0:
            skipped.append(key)
            continue
        change = (cur - base) / abs(base)
        regressed = change > threshold if lower_better else change < -threshold
        (regressions if regressed else improvements).append(
            (key, base, cur, change)
        )
    return regressions, improvements, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--metrics", default="speedups",
                    help="dotted path to the {key: number} dict to gate on")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    ap.add_argument("--lower-better", action="store_true",
                    help="treat a rise (not a fall) as the regression")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = _dig(json.load(f), args.metrics)
    with open(args.current) as f:
        cur = _dig(json.load(f), args.metrics)

    regressions, improvements, skipped = compare(
        base, cur, args.threshold, args.lower_better
    )
    for key, b, c, change in improvements:
        print(f"ok   {key}: {b} -> {c} ({change:+.1%})")
    for key in skipped:
        print(f"skip {key}: present in only one snapshot")
    for key, b, c, change in regressions:
        print(f"FAIL {key}: {b} -> {c} ({change:+.1%}, "
              f"threshold {args.threshold:.0%})", file=sys.stderr)
    if regressions:
        print(f"{len(regressions)} metric(s) regressed beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print(f"{len(improvements)} metric(s) within threshold, "
          f"{len(skipped)} skipped")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
