"""Fig. 7b on real devices: cooperative shard_map vs replicated gather.

The paper's multi-GPU speedup comes from PEs cooperating on one global
minibatch: each PE fetches only its *owned* input rows from storage and
the first forward layer redistributes them with an all-to-all, instead
of every PE gathering its full request frontier itself (the replicated
baseline Independent Minibatching pays, Fig. 7a vs 7b).

This section measures that on an actual P-device mesh: plans are built
by :class:`repro.engine.shard.ShardRunner` under ``shard_map`` (the id
all-to-alls really cross device boundaries) and the snapshot records

* per-PE edge counts (compute balance across partitioners),
* storage bytes fetched + first-layer all-to-all bytes for the
  cooperative path vs the replicated-gather bytes of independent mode
  at the SAME global batch size,
* wall-clock per plan build (shard vs sim, informational — forced-host
  CPU devices share one socket, so bytes are the gated metric).

The ``wins`` map is deterministic given the seeds, so CI gates it with
``benchmarks/compare_snapshots.py`` against the committed baseline:
``fetch/<key>`` = modeled data-movement time of the replicated baseline
over the cooperative path, using the paper's Table 1 bandwidths (fetch
at BETA=8 GB/s, all-to-all over the fast interconnect at ALPHA=50 GB/s,
same constants as ``bench_coop_vs_indep``) — must stay > 1 and not
regress; ``balance/<key>`` = mean/max per-PE edge load (1.0 = perfectly
balanced).

Device mesh: the worker re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=P`` so the parent
benchmark process keeps its single device.

    PYTHONPATH=src python -m benchmarks.run --only coop_shard
    PYTHONPATH=src python -m benchmarks.bench_coop_shard --worker  # in-proc
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

from benchmarks.common import Csv

OUT_JSON = "BENCH_coop_shard.json"
P = 4
FEAT_DIM = 128          # modeled feature width for byte counts
ALPHA = 50e9            # fast-interconnect all-to-all B/s (paper Table 1)
BETA = 8e9              # feature-fetch B/s from storage (paper Table 1)
STEPS = 4
# (global batch, fanout, layers)
SHAPES = [(256, 5, 2), (512, 5, 3)]
PARTITIONS = ("hash", "degree")


def _worker(fast: bool) -> dict:
    """Runs with P forced host devices; builds plans under shard_map."""
    import jax
    import numpy as np

    from benchmarks.common import bench_graph
    from repro.core import INVALID
    from repro.core.partition import ownership_balance
    from repro.engine import EngineConfig, MinibatchEngine

    assert len(jax.devices()) >= P, "worker needs the forced device count"
    g = bench_graph()
    shapes = SHAPES[:1] if fast else SHAPES
    payload = {
        "graph": {"V": g.num_vertices, "E": g.num_edges},
        "num_pes": P,
        "feat_dim": FEAT_DIM,
        "steps": STEPS,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "rows": [],
        "wins": {},      # gated: fetch/<key> byte ratio, balance/<key>
        "plan_ms": {},   # informational wall clocks
    }
    for partition in PARTITIONS:
        for batch, fanout, layers in shapes:
            key = f"{partition}/b{batch}_f{fanout}_l{layers}"
            cfg = EngineConfig(
                mode="cooperative", num_pes=P, local_batch=batch // P,
                num_layers=layers, fanout=fanout, sampler="labor0",
                schedule="smoothed", kappa=4, seed=0,
                partition=partition, partition_seed=0,
            )
            coop = MinibatchEngine.from_config(
                g, dataclasses.replace(cfg, executor="shard"))
            sim = MinibatchEngine.from_config(g, cfg)
            indep = MinibatchEngine.from_config(g, cfg.with_mode("independent"))

            edges_pe = np.zeros(P)
            coop_fetch = a2a_first = a2a_all = indep_fetch = 0
            off_diag = ~np.eye(P, dtype=bool)
            for s in range(STEPS):
                cp = coop.plan_at(s)       # built under shard_map
                ip = indep.plan_at(s)
                edges_pe += sum(
                    np.asarray(l.mask).sum(axis=(-2, -1)) for l in cp.layers
                ) / STEPS
                coop_fetch += int((np.asarray(cp.input_ids) != INVALID).sum())
                indep_fetch += int((np.asarray(ip.input_ids) != INVALID).sum())
                for li, layer in enumerate(cp.layers):
                    filled = np.asarray(layer.slot_to_tilde) >= 0  # (P,Q,cap)
                    cross = int((filled & off_diag[:, :, None]).sum())
                    a2a_all += cross
                    if li == layers - 1:   # input-layer redistribution
                        a2a_first += cross

            # wall clock per plan build (compile excluded), shard vs sim
            for name, eng in (("shard", coop), ("sim", sim)):
                jax.block_until_ready(eng.plan_at(0))
                t0 = time.perf_counter()
                for s in range(STEPS):
                    plan = eng.plan_at(s)
                jax.block_until_ready(plan)
                payload["plan_ms"][f"{name}/{key}"] = round(
                    (time.perf_counter() - t0) / STEPS * 1e3, 3)

            row_bytes = FEAT_DIM * 4
            fetch_bytes = coop_fetch * row_bytes
            a2a_bytes = a2a_first * row_bytes
            repl_bytes = indep_fetch * row_bytes
            # Table 1 model: fetch pays BETA, A2A rides the fast interconnect
            coop_s = fetch_bytes / BETA + a2a_bytes / ALPHA
            repl_s = repl_bytes / BETA
            bal = ownership_balance(g, coop.part)
            payload["rows"].append({
                "key": key,
                "edges_per_pe": [round(e, 1) for e in edges_pe],
                "coop_fetch_rows": coop_fetch // STEPS,
                "indep_fetch_rows": indep_fetch // STEPS,
                "a2a_first_layer_rows": a2a_first // STEPS,
                "a2a_all_layers_rows": a2a_all // STEPS,
                "coop_fetch_bytes": fetch_bytes // STEPS,
                "a2a_first_layer_bytes": a2a_bytes // STEPS,
                "replicated_bytes": repl_bytes // STEPS,
                "ownership_balance": bal,
            })
            payload["wins"][f"fetch/{key}"] = round(repl_s / coop_s, 4)
            payload["wins"][f"balance/{key}"] = round(
                float(edges_pe.mean() / edges_pe.max()), 4)
    return payload


def run(fast: bool = False) -> Csv:
    """Re-exec in a forced-multi-device subprocess, collect the snapshot."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, "-m", "benchmarks.bench_coop_shard", "--worker"]
    if fast:
        cmd.append("--fast")
    proc = subprocess.run(
        cmd, env=env, cwd=repo, capture_output=True, text=True, timeout=1800
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"coop_shard worker failed:\n{proc.stderr[-4000:]}"
        )
    with open(os.path.join(repo, OUT_JSON)) as f:
        payload = json.load(f)
    csv = Csv(["key", "coop_fetch_rows", "indep_fetch_rows",
               "a2a_first_layer_rows", "fetch_win", "edge_balance"],
              snapshot=payload)
    for row in payload["rows"]:
        csv.add(row["key"], row["coop_fetch_rows"], row["indep_fetch_rows"],
                row["a2a_first_layer_rows"],
                payload["wins"][f"fetch/{row['key']}"],
                payload["wins"][f"balance/{row['key']}"])
    worst = min(
        (v for k, v in payload["wins"].items() if k.startswith("fetch/")),
    )
    print(f"# coop_shard: modeled data-movement win min {worst}x "
          f"-> {OUT_JSON}", flush=True)
    return csv


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true",
                    help="run in-process (expects forced device count)")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.worker:
        payload = _worker(fast=args.fast)
        with open(OUT_JSON, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {OUT_JSON}", flush=True)
    else:
        run(fast=args.fast).emit()


if __name__ == "__main__":
    main()
