"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--fast]

Sections:
    monotonicity   Fig 3/6  (Thm 3.1/3.2 work curves per sampler)
    density        Thm 3.3  (induced-subgraph density vs batch)
    cache_kappa    Fig 5a/5b + Table 6 (LRU miss vs dependency kappa)
    plan_build     device-resident plan_at vs sort-based host baseline
    feature_store  Fig 5 shape through the device CLOCK tier (+ oracle gap)
    coop_shard     Fig 7b on devices: shard_map A2A bytes vs replicated gather
    coop_vs_indep  Tables 4/5/7 (per-PE counts + bandwidth-model times)
    serve          coalescing inference server vs per-request baseline
    convergence    Fig 4/9  (coop vs indep; kappa parity)
    kernels        per-kernel shape sweep
    roofline       §Roofline summary from experiments/dryrun/*.json

Every section persists a machine-readable ``BENCH_<section>.json``
snapshot (see docs/benchmarks.md for the snapshot/gate workflow).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _section(name):
    print(f"\n### {name}", flush=True)


def run_analysis_gate(out_path="BENCH_analysis.json"):
    """Run the static analyzer over src/ and persist rule counts + wall time.

    Runs first so a benchmark snapshot is never recorded against a tree
    the invariant checker rejects.
    """
    from repro.analysis import Severity, run_analysis

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    report = run_analysis([os.path.join(repo, "src")])
    payload = {
        "rule_counts": report.rule_counts(),
        "files_scanned": report.files_scanned,
        "passes_run": list(report.passes_run),
        "wall_s": round(report.wall_s, 3),
        "errors": report.count_at_least(Severity.ERROR),
        "warnings": report.count_at_least(Severity.WARNING),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# analysis: {payload['errors']} errors, "
          f"{payload['warnings']} warnings in {payload['wall_s']}s "
          f"-> {out_path}", flush=True)
    if payload["errors"]:
        for fi in report.findings:
            if fi.severity >= Severity.ERROR:
                print(fi.render(), file=sys.stderr)
        raise SystemExit("repro.analysis found errors; fix before benchmarking")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="skip the static-analysis gate / BENCH_analysis.json")
    args = ap.parse_args()

    if not args.skip_analysis:
        _section("analysis")
        t0 = time.time()
        run_analysis_gate()
        print(f"# analysis done in {time.time()-t0:.1f}s", flush=True)

    sections = {}

    def register(name, fn):
        sections[name] = fn

    from benchmarks import (
        bench_cache_kappa,
        bench_convergence,
        bench_coop_shard,
        bench_coop_vs_indep,
        bench_density,
        bench_feature_store,
        bench_kernels,
        bench_monotonicity,
        bench_plan_build,
        bench_roofline,
        bench_serve,
    )

    register("monotonicity", lambda: bench_monotonicity.run(trials=3 if args.fast else 6))
    register("density", lambda: bench_density.run(trials=4 if args.fast else 8))
    register("cache_kappa", lambda: bench_cache_kappa.run(coop=not args.fast))
    register("feature_store", lambda: bench_feature_store.run(
        coop=not args.fast, fast=args.fast))
    register("plan_build", lambda: bench_plan_build.run(fast=args.fast))
    register("coop_shard", lambda: bench_coop_shard.run(fast=args.fast))
    register("coop_vs_indep", lambda: bench_coop_vs_indep.run(fast=args.fast))
    register("serve", lambda: bench_serve.run(fast=args.fast))
    register("convergence", bench_convergence.run)
    register("kernels", bench_kernels.run)
    register("roofline", bench_roofline.run)

    todo = [args.only] if args.only else list(sections)
    for name in todo:
        t0 = time.time()
        _section(name)
        try:
            csv = sections[name]()
            csv.emit()
            # every section leaves a snapshot: the perf trajectory needs a
            # baseline to beat even for sections without a gate metric yet
            out = f"BENCH_{name}.json"
            with open(out, "w") as f:
                json.dump(csv.to_payload(name), f, indent=2, sort_keys=True)
            print(f"# {name} done in {time.time()-t0:.1f}s -> {out}",
                  flush=True)
        except Exception as e:  # keep the suite going; report at the end
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
            raise


if __name__ == "__main__":
    main()
