"""Fig. 5-shape curves through the *device* cache policy.

``bench_cache_kappa`` replays engine traces through the exact LRU
oracle; this benchmark replays the same κ-scheduled engine streams
through the tiered store's CLOCK policy (`repro.store`) and reports both
side by side — miss rate vs dependency window κ and vs cache capacity —
plus the oracle gap the differential harness bounds
(``tests/test_feature_store.py``).

Writes ``BENCH_feature_store.json`` so CI snapshots have a baseline to
gate against; stdout gets the usual CSV.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import Csv, bench_graph
from repro.core.cache import CooperativeCacheArray, LRUCache
from repro.engine import EngineConfig, MinibatchEngine
from repro.store import ClockCache

KAPPAS = [1, 4, 16, 64, None]  # None = infinite dependency window
CAP_FRACS = [2, 4, 8]          # capacity = V // frac
STEPS = 24
BATCH = 128
P = 4
WAYS = 8
OUT_JSON = "BENCH_feature_store.json"


def _trace(g, mode: str, kappa):
    """Per-step input-id arrays from one κ-scheduled engine stream."""
    num_pes = P if mode == "cooperative" else 1
    eng = MinibatchEngine.from_config(
        g,
        EngineConfig(
            mode=mode, num_pes=num_pes, local_batch=BATCH // num_pes,
            num_layers=2, sampler="labor0", fanout=5,
            schedule="smoothed", kappa=kappa, seed=11,
        ),
    )
    return [np.asarray(item.plan.input_ids) for item in eng.stream(STEPS)]


def _cap(v: int) -> int:
    return max(WAYS, v // WAYS * WAYS)  # CLOCK needs capacity % ways == 0


def run(coop: bool = True, fast: bool = False) -> Csv:
    g = bench_graph()
    V = g.num_vertices
    kappas = [1, 16, None] if fast else KAPPAS
    csv = Csv(["sweep", "mode", "kappa", "capacity", "policy", "miss_rate"])
    payload = {"V": V, "steps": STEPS, "batch": BATCH, "ways": WAYS,
               "rows": []}

    def record(sweep, mode, kappa, cap, policy, miss):
        k = kappa if kappa else "inf"
        csv.add(sweep, mode, k, cap, policy, round(miss, 4))
        payload["rows"].append({
            "sweep": sweep, "mode": mode, "kappa": k, "capacity": cap,
            "policy": policy, "miss_rate": round(miss, 4),
        })

    # -- miss rate vs kappa at capacity V/2 (Fig. 5a shape) ----------------
    cap = _cap(V // 2)
    for kappa in kappas:
        trace = _trace(g, "independent", kappa)
        clock = ClockCache(cap, ways=WAYS)
        lru = LRUCache(cap)
        for ids in trace:
            clock.access_batch(ids.ravel())
            lru.access_batch(ids.ravel())
        record("kappa", "independent", kappa, cap, "clock", clock.miss_rate)
        record("kappa", "independent", kappa, cap, "lru", lru.miss_rate)

    # -- miss rate vs capacity at fixed kappa ------------------------------
    trace = _trace(g, "independent", 16)
    for frac in CAP_FRACS:
        cap = _cap(V // frac)
        clock = ClockCache(cap, ways=WAYS)
        lru = LRUCache(cap)
        for ids in trace:
            clock.access_batch(ids.ravel())
            lru.access_batch(ids.ravel())
        record("capacity", "independent", 16, cap, "clock", clock.miss_rate)
        record("capacity", "independent", 16, cap, "lru", lru.miss_rate)

    # -- cooperative per-PE owned caches (Fig. 5b shape) -------------------
    if coop:
        cap = _cap(V // 2)
        for kappa in kappas:
            trace = _trace(g, "cooperative", kappa)
            clock = ClockCache(_cap(cap // P), ways=WAYS, num_pes=P)
            arr = CooperativeCacheArray(num_pes=P, capacity_per_pe=cap // P)
            for per_pe in trace:
                clock.access_batch(per_pe)
                arr.access(per_pe)
            record("kappa", "cooperative", kappa, cap, "clock",
                   clock.miss_rate)
            record("kappa", "cooperative", kappa, cap, "lru", arr.miss_rate)

    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT_JSON} ({len(payload['rows'])} rows)", flush=True)
    csv.snapshot = payload
    return csv


if __name__ == "__main__":
    run().emit()
