"""Plan-construction throughput: device-resident pipeline vs host baseline.

Sweeps (batch x fanout x layers) for both engine modes and times three
pipelines per shape:

* ``host/reference``  — the sort-based baseline this PR replaces: host
  seed batch (``seed_batch`` round-trips through numpy) + eagerly
  dispatched ``build_plan`` every step, exactly how the stream drove
  plan construction before ``plan_at``;
* ``device/reference`` — one end-to-end compiled ``plan_at`` step, still
  on the ``unique_padded``/``searchsorted`` frontier algebra;
* ``device/fused``     — ``plan_at`` on ``plan_backend="fused"``: the
  unique-compact / frontier-gather / expand-indptr ops (Pallas on TPU,
  their fused jnp oracles elsewhere).

Writes ``BENCH_plan_build.json`` with per-row times and a ``speedups``
map (host-baseline ms / device-fused ms per shape — the headline the
tentpole claims) plus ``backend_ratio`` (device reference / fused, the
axis the Pallas kernels move on TPU).  CI gates on the ``speedups`` map
via ``benchmarks/compare_snapshots.py``; ratios are machine-relative so
the gate survives runner variance better than raw milliseconds.
"""
from __future__ import annotations

import json
import time

import jax

from benchmarks.common import Csv, bench_graph
from repro.engine import EngineConfig, MinibatchEngine

# (global batch, fanout, layers)
SHAPES = [(64, 5, 2), (256, 5, 2), (256, 10, 2), (128, 10, 3)]
MODES = [("independent", 1), ("cooperative", 4)]
STEPS = 8
OUT_JSON = "BENCH_plan_build.json"


def _engine(g, backend, mode, num_pes, batch, fanout, layers):
    cfg = EngineConfig(
        mode=mode, num_pes=num_pes, local_batch=batch // num_pes,
        num_layers=layers, fanout=fanout, sampler="labor0",
        schedule="smoothed", kappa=4, seed=0, plan_backend=backend,
    )
    return MinibatchEngine.from_config(g, cfg)


def _time_host(eng) -> float:
    """Legacy per-step dispatch: host seeds + eager build_plan."""
    plan = eng.build_plan(eng.seed_batch(0), rng=eng.rng_state(0))
    jax.block_until_ready(plan)
    t0 = time.perf_counter()
    for s in range(STEPS):
        plan = eng.build_plan(eng.seed_batch(s), rng=eng.rng_state(s))
    jax.block_until_ready(plan)
    return (time.perf_counter() - t0) / STEPS * 1e3


def _time_device(eng) -> float:
    """One compiled plan_at step, seeds drawn on device."""
    jax.block_until_ready(eng.plan_at(0))
    t0 = time.perf_counter()
    for s in range(STEPS):
        plan = eng.plan_at(s)
    jax.block_until_ready(plan)
    return (time.perf_counter() - t0) / STEPS * 1e3


def run(fast: bool = False) -> Csv:
    g = bench_graph()
    shapes = SHAPES[:2] if fast else SHAPES
    csv = Csv(["mode", "batch", "fanout", "layers", "pipeline", "backend",
               "ms_per_step"])
    payload = {
        "graph": {"V": g.num_vertices, "E": g.num_edges},
        "steps": STEPS,
        "backend": jax.default_backend(),
        "rows": [],
        "speedups": {},       # host sort-based baseline / device fused
        "backend_ratio": {},  # device reference / device fused
    }
    for mode, num_pes in MODES:
        for batch, fanout, layers in shapes:
            key = f"{mode}/b{batch}_f{fanout}_l{layers}"
            eng_ref = _engine(g, "reference", mode, num_pes, batch, fanout,
                              layers)
            eng_fus = _engine(g, "fused", mode, num_pes, batch, fanout,
                              layers)
            times = {
                ("host", "reference"): _time_host(eng_ref),
                ("device", "reference"): _time_device(eng_ref),
                ("device", "fused"): _time_device(eng_fus),
            }
            for (pipeline, backend), ms in times.items():
                csv.add(mode, batch, fanout, layers, pipeline, backend,
                        round(ms, 3))
                payload["rows"].append({
                    "mode": mode, "batch": batch, "fanout": fanout,
                    "layers": layers, "pipeline": pipeline,
                    "backend": backend, "ms_per_step": round(ms, 4),
                })
            payload["speedups"][key] = round(
                times[("host", "reference")] / times[("device", "fused")], 3
            )
            payload["backend_ratio"][key] = round(
                times[("device", "reference")] / times[("device", "fused")],
                3,
            )
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    worst = min(payload["speedups"].items(), key=lambda kv: kv[1])
    print(f"# plan_build: fused-vs-baseline speedup min {worst[1]}x "
          f"({worst[0]}) -> {OUT_JSON}", flush=True)
    csv.snapshot = payload
    return csv
