"""Serving sweep: arrival rate x admission policy vs per-request baseline.

For each (trace kind, arrival rate, policy) cell the coalescing server
replays a seeded synthetic trace over the recsys user-item graph and
reports latency percentiles, SLO attainment, throughput, and host->
device fetched rows; the per-request FIFO baseline replays the SAME
trace without coalescing.  The gate metric is the fetched-rows
reduction (coalescing dedups overlapping ego-nets within a batch — the
paper's concavity argument applied to inference), which with the
virtual-clock ``modeled`` service time is fully deterministic and so
CI-gateable at a tight threshold.

Cache-warm numbers (the dependent-traffic reuse effect, §4.2) are
reported separately in the ``cache`` payload: at steady state the CLOCK
tier absorbs repeats for BOTH modes, so the per-batch dedup win — not
the host-link volume — is what coalescing buys on top of caching.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import Csv

RATES = (1000.0, 2000.0, 4000.0)
POLICIES_FULL = ("max_batch", "max_wait_ms", "hybrid")
POLICIES_FAST = ("max_batch", "hybrid")
KINDS_FULL = ("poisson", "bursty")
KINDS_FAST = ("poisson",)


def _setup(fast: bool):
    from repro.data.recsys import make_recsys
    from repro.models.gnn import GNNConfig, init_gnn

    if fast:
        ds = make_recsys(num_users=1024, num_items=512, edges_per_user=6,
                         feature_dim=32, seed=0)
        hidden, requests = 32, 120
    else:
        ds = make_recsys(num_users=4096, num_items=1024, seed=0)
        hidden, requests = 64, 300
    gnn = GNNConfig(model="gcn", num_layers=2, in_dim=ds.feature_dim,
                    hidden_dim=hidden, num_classes=ds.num_classes)
    params = init_gnn(jax.random.PRNGKey(0), gnn)
    return ds, gnn, params, requests


def _server(ds, gnn, params, **overrides):
    from repro.serve import GNNServer, ServeConfig

    kw = dict(num_layers=2, fanout=5, max_batch=64, max_wait_ms=10.0,
              use_cache=False)
    kw.update(overrides)
    cfg = ServeConfig(**kw)
    return GNNServer(ds.graph, ds.features, gnn, params, cfg)


def run(fast: bool = False) -> Csv:
    from repro.serve import make_trace

    ds, gnn, params, requests = _setup(fast)
    kinds = KINDS_FAST if fast else KINDS_FULL
    policies = POLICIES_FAST if fast else POLICIES_FULL

    csv = Csv(["kind", "rate_rps", "policy", "batches", "mean_batch",
               "p50_ms", "p95_ms", "p99_ms", "slo", "throughput_rps",
               "fetched_rows", "indep_fetched", "fetch_reduction"])
    wins, slo = {}, {}
    for kind in kinds:
        for rate in RATES:
            trace = make_trace(kind, requests, rate_rps=rate,
                               seed_pool=ds.user_ids, seed=1)
            rep_i = _server(ds, gnn, params).serve_independent(trace)
            for policy in policies:
                rep = _server(ds, gnn, params, policy=policy).serve_trace(
                    trace)
                red = rep_i.fetched_rows / max(rep.fetched_rows, 1)
                cell = f"{kind}_r{rate:.0f}_{policy}"
                wins[cell] = round(red, 4)
                slo[cell] = round(rep.slo_attainment, 4)
                s = rep.summary()
                csv.add(kind, int(rate), policy, s["batches"],
                        s["mean_batch"], s["p50_ms"], s["p95_ms"],
                        s["p99_ms"], s["slo_attainment"],
                        s["throughput_rps"], rep.fetched_rows,
                        rep_i.fetched_rows, round(red, 3))

    # cache-warm host-link traffic (informational, not gated): the CLOCK
    # tier absorbs repeats for both modes, so ratios compress toward 1
    cache = {}
    trace = make_trace(kinds[0], requests, rate_rps=RATES[-1],
                       seed_pool=ds.user_ids, seed=1)
    for mode, fn in (("coalesced", "serve_trace"),
                     ("independent", "serve_independent")):
        srv = _server(ds, gnn, params, policy="hybrid", use_cache=True)
        rep = getattr(srv, fn)(trace)
        cache[mode] = {
            "fetched_rows": rep.fetched_rows,
            "requested_rows": rep.requested_rows,
            "cache_hits": rep.cache_hits,
        }
    cache["host_link_ratio"] = round(
        cache["independent"]["fetched_rows"]
        / max(cache["coalesced"]["fetched_rows"], 1), 4)
    cache["requested_ratio"] = round(
        cache["independent"]["requested_rows"]
        / max(cache["coalesced"]["requested_rows"], 1), 4)

    csv.snapshot = {
        "section": "serve",
        "header": list(map(str, csv.header)),
        "rows": [list(r) for r in csv.rows],
        "wins": wins,          # fetched-rows reduction per cell (gated)
        "slo": slo,            # SLO attainment per cell (gated)
        "cache": cache,        # warm-cache reuse (informational)
        "config": {"fast": fast, "requests": requests,
                   "rates": list(RATES), "policies": list(policies),
                   "kinds": list(kinds)},
    }
    return csv


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (same settings the serve job gates on)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = run(fast=args.smoke)
    result.emit()
    with open(args.out, "w") as f:
        json.dump(result.to_payload("serve"), f, indent=2, sort_keys=True)
    print(f"# serve -> {args.out}")
