"""§Roofline summary table compiled from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run() -> Csv:
    csv = Csv(
        ["arch", "shape", "mesh", "status", "bottleneck", "compute_ms",
         "memory_ms", "collective_ms", "useful_ratio", "peak_gib"]
    )
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        csv.add("(run `python -m repro.launch.dryrun --all` first)",
                "-", "-", "-", "-", 0, 0, 0, 0, 0)
        return csv
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r["status"] != "ok":
            csv.add(r.get("arch", "?"), r.get("shape", "?"), r.get("mesh", "?"),
                    r["status"], r.get("reason", r.get("error", ""))[:40],
                    0, 0, 0, 0, 0)
            continue
        roof = r["roofline"]
        arch = r["arch"] + (f"[{r['tag']}]" if r.get("tag") else "")
        csv.add(
            arch, r["shape"], r["mesh"], "ok", roof["bottleneck"],
            round(roof["compute_s"] * 1e3, 2),
            round(roof["memory_s"] * 1e3, 2),
            round(roof["collective_s"] * 1e3, 2),
            round(roof["useful_ratio"], 3),
            round(r["memory"]["peak_per_device_gb"], 2),
        )
    return csv


if __name__ == "__main__":
    run().emit()
