"""Kernel microbenchmarks: Pallas interpret correctness + oracle timing.

Wall-clock on CPU measures the *oracle* path (the TPU kernels cannot be
timed off-hardware); the value of this table is the shape sweep — it is
the per-kernel performance harness a TPU run would fill in.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.gather.ref import gather_ref
from repro.kernels.seg_softmax.ref import seg_softmax_ref
from repro.utils.timing import bench_fn

R = np.random.default_rng(0)


def run() -> Csv:
    csv = Csv(["kernel", "shape", "us_per_call", "gbytes_per_s"])
    for S, d, n, w in [(4096, 128, 1024, 16), (16384, 256, 4096, 16)]:
        src = jnp.asarray(R.standard_normal((S, d)).astype(np.float32))
        idx = jnp.asarray(R.integers(0, S, (n, w)).astype(np.int32))
        mask = jnp.asarray(R.random((n, w)) < 0.7)
        us = bench_fn(lambda a, b, c: spmm_ref(a, b, c, mean=True), src, idx, mask)
        bytes_moved = (n * w * d + n * d) * 4
        csv.add("spmm_mean", f"{S}x{d}<-{n}x{w}", round(us, 1),
                round(bytes_moved / us / 1e3, 2))
    for V, d, n in [(65536, 128, 8192), (262144, 256, 16384)]:
        tab = jnp.asarray(R.standard_normal((V, d)).astype(np.float32))
        ids = jnp.asarray(R.integers(0, V, n).astype(np.int32))
        us = bench_fn(gather_ref, tab, ids)
        csv.add("paged_gather", f"{V}x{d}[{n}]", round(us, 1),
                round(n * d * 4 / us / 1e3, 2))
    for n, w in [(8192, 16), (32768, 32)]:
        e = jnp.asarray(R.standard_normal((n, w)).astype(np.float32))
        m = jnp.asarray(R.random((n, w)) < 0.6)
        us = bench_fn(seg_softmax_ref, e, m)
        csv.add("seg_softmax", f"{n}x{w}", round(us, 1),
                round(n * w * 4 * 2 / us / 1e3, 2))
    return csv


if __name__ == "__main__":
    run().emit()
