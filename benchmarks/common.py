"""Shared benchmark scaffolding: CSV emission + standard dataset."""
from __future__ import annotations

import sys
from dataclasses import dataclass, field


@dataclass
class Csv:
    header: list
    rows: list = field(default_factory=list)

    def add(self, *row):
        self.rows.append(row)

    def emit(self, file=sys.stdout):
        print(",".join(map(str, self.header)), file=file)
        for r in self.rows:
            print(",".join(map(str, r)), file=file)


_GRAPH_CACHE = {}


def bench_graph(scale=11, edge_factor=8, max_degree=32, seed=0):
    from repro.data import rmat_graph

    key = (scale, edge_factor, max_degree, seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = rmat_graph(
            scale=scale, edge_factor=edge_factor, max_degree=max_degree, seed=seed
        )
    return _GRAPH_CACHE[key]
