"""Shared benchmark scaffolding: CSV emission + standard dataset."""
from __future__ import annotations

import sys
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Csv:
    """Tabular benchmark result + the machine-readable snapshot payload.

    ``snapshot`` is what ``benchmarks/run.py`` persists as
    ``BENCH_<section>.json``; sections with structured gate metrics
    (speedup maps, byte ratios) attach their own dict, everything else
    gets the generic ``{header, rows}`` payload derived from the table —
    so EVERY section leaves a snapshot for the perf trajectory.
    """

    header: list
    rows: list = field(default_factory=list)
    snapshot: Optional[dict] = None

    def add(self, *row):
        self.rows.append(row)

    def emit(self, file=sys.stdout):
        print(",".join(map(str, self.header)), file=file)
        for r in self.rows:
            print(",".join(map(str, r)), file=file)

    def to_payload(self, section: str) -> dict:
        if self.snapshot is not None:
            return self.snapshot
        return {
            "section": section,
            "header": list(map(str, self.header)),
            "rows": [list(r) for r in self.rows],
        }


_GRAPH_CACHE = {}


def bench_graph(scale=11, edge_factor=8, max_degree=32, seed=0):
    from repro.data import rmat_graph

    key = (scale, edge_factor, max_degree, seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = rmat_graph(
            scale=scale, edge_factor=edge_factor, max_degree=max_degree, seed=seed
        )
    return _GRAPH_CACHE[key]
