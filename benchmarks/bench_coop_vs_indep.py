"""Tables 4/5/7: cooperative vs independent per-PE work + modeled runtime.

Counts per-PE vertices/edges/communication (Table 7 columns) for both
minibatching modes at identical global batch size, across P in {2,4,8},
then converts them to modeled stage times with the paper's bandwidth
model (Table 1) using TPU v5e constants — the CPU-container stand-in for
the paper's wall-clock Tables 4/5.

Both modes are measured through the SAME ``MinibatchEngine`` facade —
one ``EngineConfig`` per (sampler, P, partition) cell, ``with_mode``
flipping the comparison axis.

    sampling  ~ |S^l| / beta
    loading   ~ |S^L| d rho / beta  (+ A2A c/alpha for cooperative)
    F/B       ~ (flops/gamma_eff)   (+ A2A d c/alpha for cooperative)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, bench_graph
from repro.core.partition import cross_edge_ratio
from repro.engine import EngineConfig, MinibatchEngine

# TPU v5e island constants (DESIGN.md §3): alpha=ICI, beta=host/DCN, gamma=HBM
ALPHA = 50e9
BETA = 8e9
GAMMA = 819e9
FEAT_DIM = 128
HIDDEN = 1024
TRIALS = 4
LAYERS = 3
GLOBAL_BATCH = 512


def _edges_per_pe_max(plan) -> int:
    """max over PEs of each PE's TOTAL edges across layers.

    ``stats()`` reports per-layer maxima; summing those over layers would
    overestimate whenever different PEs attain different layers' maxima.
    """
    per_pe = sum(np.asarray(l.mask).sum(axis=(-2, -1)) for l in plan.layers)
    return int(np.max(per_pe))


def _measure(g, P: int, sampler_name: str, partition: str = "hash",
             trials: int = TRIALS):
    cfg = EngineConfig(
        mode="independent", num_pes=P, local_batch=GLOBAL_BATCH // P,
        num_layers=LAYERS, sampler=sampler_name, fanout=5,
        partition=partition, partition_seed=0,
    )
    # one engine pair per cell; trials vary only the step (iid schedule
    # => fresh seed batch AND fresh sampler RNG each step)
    eng_i = MinibatchEngine.from_config(g, cfg)
    eng_c = MinibatchEngine.from_config(g, cfg.with_mode("cooperative"))
    indep, coop = [], []
    for t in range(trials):
        plan_i = eng_i.build_plan(eng_i.seed_batch(t), step=t)
        s_i = plan_i.stats()
        indep.append(
            {"S3": s_i[f"S{LAYERS}"], "E": _edges_per_pe_max(plan_i), "comm": 0}
        )
        plan_c = eng_c.build_plan(eng_c.seed_batch(t), step=t)
        s_c = plan_c.stats()
        coop.append(
            {
                "S3": s_c["inputs"],
                "E": _edges_per_pe_max(plan_c),
                "comm": sum(s_c[f"comm{l+1}"] for l in range(LAYERS)),
            }
        )
    avg = lambda rows, k: float(np.mean([r[k] for r in rows]))
    c = cross_edge_ratio(g, eng_c.part)
    return (
        {"S3": avg(indep, "S3"), "E": avg(indep, "E"), "comm": 0.0},
        {"S3": avg(coop, "S3"), "E": avg(coop, "E"), "comm": avg(coop, "comm")},
        c,
    )


def _model_time_us(stats, mode: str) -> dict:
    """Paper Table 1 bandwidth model -> microseconds per stage."""
    f = 4  # bytes/feature
    load = stats["S3"] * FEAT_DIM * f / BETA
    flops = 2 * stats["E"] * FEAT_DIM * HIDDEN  # 1st-layer-dominated F/B proxy
    fb = 3 * flops / (0.3 * GAMMA * 100)  # effective flop rate proxy
    comm = stats["comm"] * HIDDEN * f / ALPHA if mode == "coop" else 0.0
    return {
        "load_us": 1e6 * (load + (stats["comm"] * FEAT_DIM * f / ALPHA if mode == "coop" else 0)),
        "fb_us": 1e6 * (fb + comm),
    }


def run(fast: bool = False) -> Csv:
    g = bench_graph(scale=11 if fast else 12)
    trials = 2 if fast else TRIALS
    ps = (2, 4) if fast else (2, 4, 8)
    csv = Csv(
        ["sampler", "P", "mode", "partition", "S3_perPE", "E_perPE",
         "comm_perPE", "cross_edge_c", "load_us_model", "fb_us_model"]
    )
    wins = {}
    for sampler_name in ("labor0", "ns"):
        for P in ps:
            for partition in ("hash", "bfs"):
                indep, coop, c = _measure(
                    g, P, sampler_name, partition, trials=trials
                )
                for mode, st in (("indep", indep), ("coop", coop)):
                    t = _model_time_us(st, mode)
                    csv.add(
                        sampler_name, P, mode, partition,
                        int(st["S3"]), int(st["E"]), int(st["comm"]),
                        round(c, 3), round(t["load_us"], 1), round(t["fb_us"], 1),
                    )
                # gate metric: per-PE input-row reduction from cooperation
                # (Table 5's work win) — hash-keyed sampling makes every
                # count deterministic, so CI gates at a tight threshold
                wins[f"{sampler_name}_P{P}_{partition}"] = round(
                    indep["S3"] / max(coop["S3"], 1.0), 4
                )
    csv.snapshot = {
        "section": "coop_vs_indep",
        "header": list(map(str, csv.header)),
        "rows": [list(r) for r in csv.rows],
        "wins": wins,
        "config": {"fast": fast, "trials": trials, "P": list(ps)},
    }
    return csv


if __name__ == "__main__":
    run().emit()
