"""Tables 4/5/7: cooperative vs independent per-PE work + modeled runtime.

Counts per-PE vertices/edges/communication (Table 7 columns) for both
minibatching modes at identical global batch size, across P in {2,4,8},
then converts them to modeled stage times with the paper's bandwidth
model (Table 1) using TPU v5e constants — the CPU-container stand-in for
the paper's wall-clock Tables 4/5.

    sampling  ~ |S^l| / beta
    loading   ~ |S^L| d rho / beta  (+ A2A c/alpha for cooperative)
    F/B       ~ (flops/gamma_eff)   (+ A2A d c/alpha for cooperative)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, bench_graph
from repro.core.cooperative import (
    CoopCapacityPlan,
    SimExecutor,
    build_cooperative_minibatch,
    plan_stats,
)
from repro.core.minibatch import CapacityPlan, build_minibatch, epoch_stats
from repro.core.partition import cross_edge_ratio, hash_partition, make_partition
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler

# TPU v5e island constants (DESIGN.md §3): alpha=ICI, beta=host/DCN, gamma=HBM
ALPHA = 50e9
BETA = 8e9
GAMMA = 819e9
FEAT_DIM = 128
HIDDEN = 1024
TRIALS = 4
LAYERS = 3
GLOBAL_BATCH = 512


def _measure(g, P: int, sampler_name: str, partition: str = "hash"):
    b = GLOBAL_BATCH // P
    part = make_partition(partition, g, P)
    owner = np.asarray(part.owner)
    owned = [np.nonzero(owner == p)[0] for p in range(P)]
    IM = np.iinfo(np.int32).max
    sampler = make_sampler(sampler_name, fanout=5)
    caps_i = CapacityPlan.geometric(b, LAYERS, 5, g.num_vertices)
    caps_c = CoopCapacityPlan.geometric(b, LAYERS, 5, g.num_vertices, P)
    ex = SimExecutor(P)
    indep, coop = [], []
    for t in range(TRIALS):
        rng = DependentRNG(base_seed=31 * t, kappa=1, step=0)
        rng_np = np.random.default_rng(t)
        # independent: P separate batches (max per-PE counts)
        st_i = {"S3": 0, "E": 0}
        for p in range(P):
            seeds = rng_np.choice(g.num_vertices, size=b, replace=False)
            mb = build_minibatch(
                g, sampler, jnp.asarray(seeds, jnp.int32), rng, LAYERS, caps_i
            )
            s = epoch_stats(mb)
            st_i["S3"] = max(st_i["S3"], s[f"S{LAYERS}"])
            st_i["E"] = max(st_i["E"], sum(s[f"E{l}"] for l in range(LAYERS)))
        indep.append(st_i)
        # cooperative: one global batch, owned seeds
        seeds = np.full((P, b), IM, np.int32)
        for p in range(P):
            seeds[p] = rng_np.choice(owned[p], size=min(b, len(owned[p])), replace=False)
        mb = build_cooperative_minibatch(
            g, sampler, part, jnp.asarray(seeds), rng, LAYERS, caps_c, ex
        )
        s = plan_stats(mb, ex)
        coop.append(
            {
                "S3": s["inputs"],
                "E": sum(s[f"E{l}"] for l in range(LAYERS)),
                "comm": sum(s[f"comm{l+1}"] for l in range(LAYERS)),
            }
        )
    avg = lambda rows, k: float(np.mean([r[k] for r in rows]))
    c = cross_edge_ratio(g, part)
    return (
        {"S3": avg(indep, "S3"), "E": avg(indep, "E"), "comm": 0.0},
        {"S3": avg(coop, "S3"), "E": avg(coop, "E"), "comm": avg(coop, "comm")},
        c,
    )


def _model_time_us(stats, mode: str) -> dict:
    """Paper Table 1 bandwidth model -> microseconds per stage."""
    f = 4  # bytes/feature
    load = stats["S3"] * FEAT_DIM * f / BETA
    flops = 2 * stats["E"] * FEAT_DIM * HIDDEN  # 1st-layer-dominated F/B proxy
    fb = 3 * flops / (0.3 * GAMMA * 100)  # effective flop rate proxy
    comm = stats["comm"] * HIDDEN * f / ALPHA if mode == "coop" else 0.0
    return {
        "load_us": 1e6 * (load + (stats["comm"] * FEAT_DIM * f / ALPHA if mode == "coop" else 0)),
        "fb_us": 1e6 * (fb + comm),
    }


def run() -> Csv:
    g = bench_graph(scale=12)
    csv = Csv(
        ["sampler", "P", "mode", "partition", "S3_perPE", "E_perPE",
         "comm_perPE", "cross_edge_c", "load_us_model", "fb_us_model"]
    )
    for sampler_name in ("labor0", "ns"):
        for P in (2, 4, 8):
            for partition in ("hash", "bfs"):
                indep, coop, c = _measure(g, P, sampler_name, partition)
                for mode, st in (("indep", indep), ("coop", coop)):
                    t = _model_time_us(st, mode)
                    csv.add(
                        sampler_name, P, mode, partition,
                        int(st["S3"]), int(st["E"]), int(st["comm"]),
                        round(c, 3), round(t["load_us"], 1), round(t["fb_us"], 1),
                    )
    return csv


if __name__ == "__main__":
    run().emit()
