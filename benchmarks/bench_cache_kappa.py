"""Fig 5a/5b + Table 6: LRU miss rate vs dependency window kappa.

``--coop`` additionally runs the cooperative per-PE owned caches
(Fig 5b): cooperative feature loading deduplicates cache contents across
PEs, so the global effective capacity grows P-fold.

Both input-id streams come from ``MinibatchEngine.stream`` — one engine
per (mode, kappa) cell, identical global batch size.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, bench_graph
from repro.core.cache import CooperativeCacheArray, LRUCache
from repro.engine import EngineConfig, MinibatchEngine

KAPPAS = [1, 4, 16, 64, 256, None]  # None = infinite dependency
STEPS = 24
BATCH = 128
P = 4


def _input_ids(g, mode: str, kappa):
    num_pes = P if mode == "cooperative" else 1
    eng = MinibatchEngine.from_config(
        g,
        EngineConfig(
            mode=mode, num_pes=num_pes, local_batch=BATCH // num_pes,
            num_layers=2, sampler="labor0", fanout=5,
            schedule="smoothed", kappa=kappa, seed=11,
        ),
    )
    for item in eng.stream(num_steps=STEPS):
        yield np.asarray(item.plan.input_ids)  # (P, capL) when cooperative


def run(coop: bool = True) -> Csv:
    g = bench_graph()
    cache_capacity = g.num_vertices // 2
    csv = Csv(["mode", "kappa", "miss_rate"])
    for kappa in KAPPAS:
        c = LRUCache(capacity=cache_capacity)
        for ids in _input_ids(g, "independent", kappa):
            c.access_batch(ids.ravel())
        csv.add("independent", kappa if kappa else "inf", round(c.miss_rate, 4))
    if coop:
        for kappa in KAPPAS:
            arr = CooperativeCacheArray(num_pes=P, capacity_per_pe=cache_capacity // P)
            for per_pe in _input_ids(g, "cooperative", kappa):
                arr.access(per_pe)
            csv.add("cooperative", kappa if kappa else "inf", round(arr.miss_rate, 4))
    return csv


if __name__ == "__main__":
    run().emit()
