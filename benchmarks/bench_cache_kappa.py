"""Fig 5a/5b + Table 6: LRU miss rate vs dependency window kappa.

``--coop`` additionally runs the cooperative per-PE owned caches
(Fig 5b): cooperative feature loading deduplicates cache contents across
PEs, so the global effective capacity grows P-fold.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, bench_graph
from repro.core.cache import CooperativeCacheArray, LRUCache
from repro.core.cooperative import (
    CoopCapacityPlan,
    SimExecutor,
    build_cooperative_minibatch,
)
from repro.core.minibatch import CapacityPlan, build_minibatch
from repro.core.partition import hash_partition
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler

KAPPAS = [1, 4, 16, 64, 256, None]  # None = infinite dependency
STEPS = 24
BATCH = 128
P = 4


def _indep_stream(g, kappa, seed=0):
    sampler = make_sampler("labor0", fanout=5)
    caps = CapacityPlan.geometric(BATCH, 2, 5, g.num_vertices)
    rng_np = np.random.default_rng(seed)
    for step in range(STEPS):
        seeds = rng_np.choice(g.num_vertices, size=BATCH, replace=False)
        rng = DependentRNG(base_seed=11, kappa=kappa, step=step)
        mb = build_minibatch(g, sampler, jnp.asarray(seeds, jnp.int32), rng, 2, caps)
        yield np.asarray(mb.input_ids)


def _coop_stream(g, kappa, seed=0):
    part = hash_partition(g.num_vertices, P)
    owner = np.asarray(part.owner)
    owned = [np.nonzero(owner == p)[0] for p in range(P)]
    sampler = make_sampler("labor0", fanout=5)
    caps = CoopCapacityPlan.geometric(BATCH // P, 2, 5, g.num_vertices, P)
    ex = SimExecutor(P)
    IM = np.iinfo(np.int32).max
    for step in range(STEPS):
        rng_np = np.random.default_rng(seed + step)
        seeds = np.full((P, BATCH // P), IM, np.int32)
        for p in range(P):
            seeds[p] = rng_np.choice(owned[p], size=BATCH // P, replace=False)
        rng = DependentRNG(base_seed=11, kappa=kappa, step=step)
        mb = build_cooperative_minibatch(
            g, sampler, part, jnp.asarray(seeds), rng, 2, caps, ex
        )
        yield np.asarray(mb.input_ids)  # (P, capL)


def run(coop: bool = True) -> Csv:
    g = bench_graph()
    cache_capacity = g.num_vertices // 2
    csv = Csv(["mode", "kappa", "miss_rate"])
    for kappa in KAPPAS:
        c = LRUCache(capacity=cache_capacity)
        for ids in _indep_stream(g, kappa):
            c.access_batch(ids)
        csv.add("independent", kappa if kappa else "inf", round(c.miss_rate, 4))
    if coop:
        for kappa in KAPPAS:
            arr = CooperativeCacheArray(num_pes=P, capacity_per_pe=cache_capacity // P)
            for per_pe in _coop_stream(g, kappa):
                arr.access(per_pe)
            csv.add("cooperative", kappa if kappa else "inf", round(arr.miss_rate, 4))
    return csv


if __name__ == "__main__":
    run().emit()
