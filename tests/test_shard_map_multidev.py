"""Multi-device shard_map path: ShardExecutor == SimExecutor.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test session keeps its single device (per the launch brief).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.cooperative import (
        CoopCapacityPlan, SimExecutor, ShardExecutor,
        build_cooperative_minibatch, redistribute)
    from repro.core.partition import hash_partition
    from repro.core.rng import DependentRNG
    from repro.core.samplers import make_sampler
    from repro.data import rmat_graph

    PE, B, L = 8, 32, 2
    g = rmat_graph(scale=10, edge_factor=8, max_degree=32, seed=0)
    part = hash_partition(g.num_vertices, PE)
    owner = np.asarray(part.owner)
    rng_np = np.random.default_rng(0)
    IM = np.iinfo(np.int32).max
    seeds = np.full((PE, B), IM, np.int32)
    for p in range(PE):
        own = np.nonzero(owner == p)[0]
        seeds[p] = rng_np.choice(own, size=B, replace=False)
    seeds = jnp.asarray(seeds)
    caps = CoopCapacityPlan.geometric(B, L, 5, g.num_vertices, PE)
    sampler = make_sampler("labor0", fanout=5)
    rng = DependentRNG(3, 1, 0)
    feat = jnp.asarray(np.random.default_rng(1)
                       .standard_normal((g.num_vertices, 8)).astype(np.float32))

    # --- SimExecutor (oracle) ---
    ex_sim = SimExecutor(PE)
    mb_sim = build_cooperative_minibatch(g, sampler, part, seeds, rng, L, caps, ex_sim)
    H_sim = jax.vmap(lambda ids: jnp.where(
        (ids != IM)[:, None], feat[jnp.clip(ids, 0, g.num_vertices - 1)], 0.0
    ))(mb_sim.input_ids)
    Ht_sim = redistribute(ex_sim, mb_sim.layers[L - 1], H_sim, caps.tilde_caps[L - 1])

    # --- ShardExecutor over a real 8-device mesh ---
    mesh = jax.make_mesh((PE,), ("data",))
    ex_sh = ShardExecutor(PE, axis_name="data")

    def per_pe(seeds_p):
        mb = build_cooperative_minibatch(g, sampler, part,
                                         seeds_p.reshape(-1), rng, L, caps, ex_sh)
        H = jnp.where((mb.input_ids != IM)[:, None],
                      feat[jnp.clip(mb.input_ids, 0, g.num_vertices - 1)], 0.0)
        Ht = redistribute(ex_sh, mb.layers[L - 1], H, caps.tilde_caps[L - 1])
        return Ht[None], mb.layers[L - 1].tilde_ids[None]

    with mesh:
        f = shard_map(per_pe, mesh=mesh, in_specs=(P("data", None),),
                      out_specs=(P("data", None, None), P("data", None)),
                      check_rep=False)
        Ht_sh, tid_sh = jax.jit(f)(seeds)

    # same tilde ids and same redistributed embeddings per PE
    np.testing.assert_array_equal(
        np.asarray(tid_sh), np.asarray(mb_sim.layers[L - 1].tilde_ids))
    np.testing.assert_allclose(
        np.asarray(Ht_sh), np.asarray(Ht_sim), atol=1e-6)
    print("SHARD_MAP_MATCHES_SIM")
    """
)


@pytest.mark.slow
def test_shard_executor_matches_sim_executor():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        timeout=560,
    )
    assert "SHARD_MAP_MATCHES_SIM" in out.stdout, out.stderr[-3000:]
