"""Oracle-differential harness for the tiered feature store.

The device CLOCK cache (`repro.store`) must track the exact LRU oracle
(`repro.core.cache.LRUCache`) that the Fig. 5 / Table 6 numbers are
defined against.  The harness replays identical id traces through both
policies and asserts:

* hit-rate gap vs the oracle is bounded (two-sided 5 points in the
  LRU-meaningful regime where capacity comfortably exceeds the per-batch
  working set; one-sided — CLOCK never collapses below LRU — in the
  thrash regime where exact LRU degenerates to sequential flooding),
* fetch counters agree exactly with ``FeatureStore.count_fetched``
  accounting (requested == sum of per-batch unique valid ids,
  hits + misses == requested, host fetches == misses),
* gathered features are bit-exact with the uncached
  ``FeatureStore.gather`` across independent (1-D and stacked),
  cooperative, and dependent engine modes — including warm-cache
  second passes over the same plans.

Plus the regression test pinning the vectorized ``LRUCache.access_batch``
to its per-element sequential semantics.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cache import LRUCache
from repro.core.feature_loader import FeatureStore
from repro.core.graph import INVALID
from repro.engine import CacheConfig, EngineConfig, MinibatchEngine
from repro.store import (
    ClockCache,
    TieredFeatureStore,
    clock_access,
    clock_init,
    hash_set,
    probe_ref,
    tag_probe_pallas,
    unique_rows,
)

V = 2048
BATCH = 128
STEPS = 40
KAPPA = {"iid": 1, "smoothed": 8, "nested": 4}  # κ·b < V keeps nested unsaturated


# ---------------------------------------------------------------------------
# trace generators — the κ schedules the engine drives (§3.2)
# ---------------------------------------------------------------------------
def make_trace(schedule: str, kappa: int = 8, steps: int = STEPS,
               batch: int = BATCH, num_ids: int = V, seed: int = 0):
    """List of (batch,) id arrays under an iid / smoothed / nested schedule."""
    rng = np.random.default_rng(seed)
    if schedule == "iid":
        return [rng.integers(0, num_ids, batch) for _ in range(steps)]
    if schedule == "smoothed":
        out, cur = [], rng.integers(0, num_ids, batch)
        for _ in range(steps):
            resample = rng.random(batch) < 1.0 / kappa
            cur = np.where(resample, rng.integers(0, num_ids, batch), cur)
            out.append(cur.copy())
        return out
    if schedule == "nested":
        out = []
        for s in range(steps):
            if s % kappa == 0:
                pool = np.random.default_rng(seed + 7 * (s // kappa)).choice(
                    num_ids, size=min(kappa * batch, num_ids), replace=False
                )
            out.append(rng.choice(pool, size=batch, replace=False))
        return out
    raise ValueError(schedule)


def replay(cache, trace):
    for ids in trace:
        cache.access_batch(ids)
    return cache.hit_rate if hasattr(cache, "hit_rate") else None


def lru_hit_rate(capacity, trace):
    lru = LRUCache(capacity)
    for ids in trace:
        lru.access_batch(ids)
    total = lru.hits + lru.misses
    return lru.hits / total if total else 0.0


# ---------------------------------------------------------------------------
# differential: CLOCK vs exact-LRU oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("schedule", ["iid", "smoothed", "nested"])
def test_clock_tracks_lru_oracle(schedule):
    """≤ 5-point hit-rate gap where LRU is meaningful (capacity ≳ 2×batch)."""
    cap = V // 2
    trace = make_trace(schedule, kappa=KAPPA[schedule], seed=3)
    clock = ClockCache(cap, ways=8)
    replay(clock, trace)
    lru = lru_hit_rate(cap, trace)
    assert clock.hits + clock.misses == sum(
        len(np.unique(t)) for t in trace
    )
    assert abs(clock.hit_rate - lru) <= 0.05, (clock.hit_rate, lru)


@pytest.mark.parametrize("cap_frac", [16, 32])
def test_clock_never_collapses_below_lru(cap_frac):
    """Thrash regime (capacity ≲ per-batch working set): exact LRU
    sequential-floods while CLOCK's random residents keep serving —
    require only the one-sided bound."""
    cap = max(16, (V // cap_frac) // 8 * 8)
    trace = make_trace("iid", seed=5)
    clock = ClockCache(cap, ways=8)
    replay(clock, trace)
    lru = lru_hit_rate(cap, trace)
    assert clock.hit_rate >= lru - 0.05, (clock.hit_rate, lru)


def test_dependent_kappa_raises_hit_rate():
    """The paper's §4.2 effect: larger κ ⇒ more inter-batch overlap ⇒
    higher cache hit rate — visible through the device CLOCK policy."""
    cap = V // 2
    rates = []
    for kappa in (1, 8, 32):
        sched = "iid" if kappa == 1 else "smoothed"
        trace = make_trace(sched, kappa=kappa, seed=11)
        clock = ClockCache(cap, ways=8)
        replay(clock, trace)
        rates.append(clock.hit_rate)
    assert rates[0] < rates[1] < rates[2], rates


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["iid", "smoothed", "nested"])
@pytest.mark.parametrize("cap_frac", [2, 4, 8])
def test_clock_vs_lru_sweep(schedule, cap_frac):
    """Full trace-replay differential sweep (capacity × schedule grid);
    every cell sits in the LRU-meaningful regime (capacity ≥ 2×batch)."""
    cap = (V // cap_frac) // 8 * 8
    trace = make_trace(schedule, kappa=KAPPA[schedule], seed=13)
    clock = ClockCache(cap, ways=8)
    replay(clock, trace)
    lru = lru_hit_rate(cap, trace)
    assert abs(clock.hit_rate - lru) <= 0.05, (cap, clock.hit_rate, lru)


def test_cooperative_per_pe_caches_are_disjoint_and_tracked():
    """P per-PE caches over owned ids: disjoint residents, per-PE stats."""
    P, cap = 4, 256
    rng = np.random.default_rng(17)
    clock = ClockCache(cap, ways=8, num_pes=P)
    for _ in range(20):
        # row p only ever requests ids ≡ p (mod P) — ownership partition
        ids = np.stack(
            [rng.choice(V // P, 64, replace=False) * P + p for p in range(P)]
        )
        clock.access_batch(ids)
    tags = np.asarray(clock.state.tags)
    for p in range(P):
        resident = tags[p][tags[p] != np.int32(INVALID)]
        assert np.all(resident % P == p)
    per_pe = np.asarray(clock.state.hits) + np.asarray(clock.state.misses)
    assert np.all(per_pe == 20 * 64)


# ---------------------------------------------------------------------------
# fetch accounting — must match FeatureStore.count_fetched exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_pes", [1, 3])
def test_fetch_accounting_matches_count_fetched(num_pes):
    rng = np.random.default_rng(23)
    feats = rng.normal(size=(V, 16)).astype(np.float32)
    ref = FeatureStore(jnp.asarray(feats))
    store = TieredFeatureStore(feats, capacity=256, ways=8, num_pes=num_pes)
    expect_requested = 0
    for step in range(12):
        ids = rng.integers(0, V, (num_pes, 96)).astype(np.int32)
        ids[rng.random(ids.shape) < 0.1] = np.int32(INVALID)
        store.gather(ids if num_pes > 1 else ids[0])
        expect_requested += ref.count_fetched(ids)
    assert store.requested == expect_requested
    assert store.hits + store.misses == store.requested
    assert store.fetched_rows == store.misses  # every miss crosses the link


# ---------------------------------------------------------------------------
# bit-exact gather through the engine, all three modes
# ---------------------------------------------------------------------------
def _engine(small_graph, small_dataset, cache_capacity=256, **kw):
    cfg = EngineConfig(
        local_batch=32, num_layers=2, fanout=4, sampler="ns",
        cache=CacheConfig(enabled=True, capacity=cache_capacity), **kw,
    )
    return MinibatchEngine.from_config(small_graph, cfg, dataset=small_dataset)


def _assert_cached_gather_exact(eng, steps=3):
    """Two passes over the same plans: cold fills then warm hits, both
    bit-exact against the uncached FeatureStore path."""
    plans = [
        eng.build_plan(eng.seed_batch(s), rng=eng.rng_at(s)) for s in range(steps)
    ]
    for _pass in range(2):
        for plan in plans:
            got = np.asarray(eng.gather_features(plan))
            want = np.asarray(plan.gather_inputs(eng.store))
            assert got.shape == want.shape
            assert np.array_equal(got, want)
    assert eng.tiered.hits > 0  # the warm pass actually exercised hits


def test_gather_bit_exact_independent_1d(small_graph, small_dataset):
    eng = _engine(small_graph, small_dataset)
    plans = [
        eng.build_plan(eng.seed_batch(s)[0], rng=eng.rng_at(s))
        for s in range(3)
    ]
    for _pass in range(2):
        for plan in plans:
            assert plan.input_ids.ndim == 1
            got = np.asarray(eng.gather_features(plan))
            want = np.asarray(plan.gather_inputs(eng.store))
            assert np.array_equal(got, want)


def test_gather_bit_exact_independent_stacked(small_graph, small_dataset):
    eng = _engine(small_graph, small_dataset, num_pes=2)
    _assert_cached_gather_exact(eng)


def test_gather_bit_exact_cooperative(small_graph, small_dataset):
    eng = _engine(
        small_graph, small_dataset, mode="cooperative", num_pes=2,
        cache_capacity=512,
    )
    _assert_cached_gather_exact(eng)


def test_gather_bit_exact_dependent_nested(small_graph, small_dataset):
    eng = _engine(small_graph, small_dataset, schedule="nested", kappa=4)
    plans = [
        eng.build_plan(eng.seed_batch(s), rng=eng.rng_at(s)) for s in range(6)
    ]
    for plan in plans:
        got = np.asarray(eng.gather_features(plan))
        want = np.asarray(plan.gather_inputs(eng.store))
        assert np.array_equal(got, want)
    # κ=4 nested re-carves one group batch: warm hits must appear within
    # the first group already
    assert eng.tiered.hits > 0


def test_stream_prefetches_features_through_cache(small_graph, small_dataset):
    eng = _engine(small_graph, small_dataset)
    items = list(eng.stream(3, prefetch=2, fetch_features=True))
    assert len(items) == 3 and eng.tiered.batches == 3
    for item in items:
        want = np.asarray(item.plan.gather_inputs(eng.store))
        assert np.array_equal(np.asarray(item.features), want)
    plain = list(eng.stream(2, prefetch=1))
    assert all(item.features is None for item in plain)


# ---------------------------------------------------------------------------
# CLOCK / kernel unit checks
# ---------------------------------------------------------------------------
def test_clock_semantics_small():
    """Hand-traceable S=1, W=2 sequence exercising both CLOCK branches:
    the full-circle sweep (all ref bits set → evict at the hand) and the
    second-chance pick of the first clear ref bit."""
    state = clock_init(capacity=2, ways=2)
    u = lambda *ids: unique_rows(jnp.asarray([ids], jnp.int32))
    state, acc = clock_access(state, u(1, 2))  # cold: both miss, both admitted
    assert not bool(acc.hit.any()) and int(state.misses[0]) == 2
    state, acc = clock_access(state, u(1))     # hit against resident tag
    assert bool(acc.hit.all()) and int(state.hits[0]) == 1
    # both ref bits set -> full-circle sweep clears them and evicts the
    # hand position (way 0, id 1); survivor 2's ref bit is now clear
    state, acc = clock_access(state, u(3))
    tags = set(np.asarray(state.tags).ravel().tolist())
    assert tags == {2, 3}
    # 3 was admitted with ref set, 2's bit is clear -> second chance
    # evicts 2, keeps 3
    state, acc = clock_access(state, u(4))
    tags = set(np.asarray(state.tags).ravel().tolist())
    assert tags == {3, 4}


def test_clock_requested_counts_unique_valid():
    state = clock_init(capacity=16, ways=4)
    ids = jnp.asarray([[5, 5, 7, INVALID, 7, 9]], jnp.int32)
    state, _ = clock_access(state, unique_rows(ids))
    assert int(state.requested[0]) == 3


def test_hash_set_in_range():
    ids = jnp.arange(5000, dtype=jnp.int32)
    s = np.asarray(hash_set(ids, 64))
    assert s.min() >= 0 and s.max() < 64
    # multiplicative hash should spread consecutive ids across sets
    counts = np.bincount(s, minlength=64)
    assert counts.max() < 5 * counts.mean()


def test_tag_probe_pallas_matches_reference():
    rng = np.random.default_rng(31)
    S, W, n = 64, 4, 512
    tags = rng.integers(0, 2000, (S, W)).astype(np.int32)
    tags[rng.random((S, W)) < 0.3] = np.int32(INVALID)
    sets = rng.integers(0, S, n).astype(np.int32)
    ids = np.where(
        rng.random(n) < 0.2, -1, tags[sets, rng.integers(0, W, n)]
    ).astype(np.int32)
    got = np.asarray(
        tag_probe_pallas(
            jnp.asarray(tags), jnp.asarray(sets), jnp.asarray(ids),
            block_n=256, page=32, interpret=True,
        )
    )
    want = np.asarray(probe_ref(jnp.asarray(tags), jnp.asarray(sets),
                                jnp.asarray(ids)))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# LRU oracle regression: vectorized batch path == sequential semantics
# ---------------------------------------------------------------------------
def _lru_reference(capacity, trace):
    """The original per-element walk, inlined as the pinned reference."""
    from collections import OrderedDict

    store, hits, misses, order = OrderedDict(), 0, 0, []
    for ids in trace:
        ids = np.unique(np.asarray(ids).ravel().astype(np.int64))
        ids = ids[ids != np.iinfo(np.int32).max]
        for v in ids.tolist():
            if v in store:
                store.move_to_end(v)
                hits += 1
            else:
                misses += 1
                store[v] = True
                if len(store) > capacity:
                    store.popitem(last=False)
        order.append(list(store))
    return hits, misses, order


@pytest.mark.parametrize("capacity", [4, 64, 200])
def test_lru_batch_path_bit_identical(capacity):
    rng = np.random.default_rng(37)
    trace = []
    for t in range(120):
        kind = t % 5
        if kind == 0:       # uniform churn
            ids = rng.integers(0, 3 * capacity, rng.integers(1, 2 * capacity))
        elif kind == 1:     # hot set, mostly hits
            ids = rng.integers(0, capacity // 2 + 2, rng.integers(1, capacity + 3))
        elif kind == 2:     # sequential scan (front-zone coupling)
            ids = np.arange(t % (2 * capacity), t % (2 * capacity) + capacity // 2 + 1)
        elif kind == 3:     # INVALID padding must be ignored
            ids = np.concatenate(
                [rng.integers(0, capacity, 5), [np.iinfo(np.int32).max] * 3]
            )
        else:               # heavy eviction-zone overlap (the coupled case)
            ids = rng.integers(0, capacity + capacity // 4 + 2,
                               rng.integers(1, capacity + 1))
        trace.append(ids)
    cache = LRUCache(capacity)
    for step, ids in enumerate(trace):
        cache.access_batch(ids)
        h, m, order = _lru_reference(capacity, trace[: step + 1])
        assert (cache.hits, cache.misses) == (h, m), step
        assert cache.lru_keys().tolist() == order[-1], step


def test_lru_batch_path_is_batch_size_invariant():
    """One big batch == same ids one at a time (they're deduped+sorted)."""
    rng = np.random.default_rng(41)
    ids = rng.integers(0, 500, 300)
    a, b = LRUCache(128), LRUCache(128)
    a.access_batch(ids)
    for v in np.unique(ids):
        b.access_batch(np.asarray([v]))
    assert (a.hits, a.misses) == (b.hits, b.misses)
    assert a.lru_keys().tolist() == b.lru_keys().tolist()
