"""Compat shim: collect property-based modules without ``hypothesis``.

When hypothesis is installed this re-exports the real API unchanged.
When it is absent, ``@given`` tests become zero-argument tests that
skip at runtime, and ``strategies``/``settings`` are inert stand-ins —
so the plain unit tests in the same modules still collect and run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque placeholder; only ever passed back to the stub ``given``."""

        def __repr__(self):
            return "<stub strategy (hypothesis not installed)>"

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return lambda *args, **kwargs: _Strategy()

    strategies = _Strategies()

    def given(*_args, **_kwargs):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the strategy-bound parameters of the original test.
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            skipped.__module__ = fn.__module__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
