"""Dependent minibatching (§3.2/§4.2): locality grows with kappa."""
import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.cache import CooperativeCacheArray, LRUCache
from repro.core.minibatch import CapacityPlan, build_minibatch
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler


def _input_ids_stream(graph, kappa, steps, batch=64, seed=0):
    sampler = make_sampler("labor0", fanout=5)
    caps = CapacityPlan.geometric(batch, 2, 5, graph.num_vertices)
    rng_np = np.random.default_rng(seed)
    out = []
    for step in range(steps):
        seeds = rng_np.choice(graph.num_vertices, size=batch, replace=False)
        rng = DependentRNG(base_seed=11, kappa=kappa, step=step)
        mb = build_minibatch(
            graph, sampler, jnp.asarray(seeds, jnp.int32), rng, 2, caps
        )
        out.append(np.asarray(mb.input_ids))
    return out


def test_lru_cache_exact_semantics():
    c = LRUCache(capacity=2)
    assert c.access_batch(np.asarray([1, 2])) == 2      # cold
    assert c.access_batch(np.asarray([1])) == 0         # hit
    assert c.access_batch(np.asarray([3])) == 1         # evicts 2 (LRU)
    assert c.access_batch(np.asarray([2])) == 1         # miss again
    assert c.hits == 1 and c.misses == 4


def test_cache_miss_rate_drops_with_kappa(small_graph):
    """Fig 5a: higher kappa => lower LRU miss rate, same sampler."""
    rates = {}
    for kappa in (1, 16):
        cache = LRUCache(capacity=small_graph.num_vertices // 4)
        for ids in _input_ids_stream(small_graph, kappa, steps=12):
            cache.access_batch(ids)
        rates[kappa] = cache.miss_rate
    assert rates[16] < rates[1], rates


def test_kappa_unbiased_per_step(small_graph):
    """Every step of a dependent schedule is still a valid LABOR sample:
    expected per-seed edge count stays ~min(deg, k) at any step."""
    sampler = make_sampler("labor0", fanout=5)
    seeds = frontier.pad_to(jnp.arange(128, dtype=jnp.int32), 128)
    deg = np.asarray(small_graph.degrees)[:128]
    expect = np.minimum(deg, 5)
    errs = []
    for step in (0, 3, 7):  # mid-window steps have interpolated variates
        counts = []
        for base in range(8):
            rng = DependentRNG(base_seed=base * 7, kappa=8, step=step)
            ls = sampler.sample_layer(small_graph, seeds, rng, 0)
            counts.append(np.asarray(ls.mask).sum(1))
        errs.append(np.abs(np.stack(counts).mean(0) - expect).mean())
    assert max(errs) < 1.2, errs


def test_cooperative_cache_no_duplication():
    """Owned-only caching: the same id never occupies two PE caches."""
    arr = CooperativeCacheArray(num_pes=2, capacity_per_pe=8)
    a = np.asarray([[1, 2, 3], [4, 5, 6]])
    arr.access(a)
    arr.access(a)
    assert arr.miss_rate == 0.5  # first pass misses, second all hits
