"""Cooperative Minibatching (Alg. 1) invariants under SimExecutor.

The key semantics test: the cooperative plan + redistribution delivers
EXACTLY the same embeddings a monolithic gather would — i.e. cooperation
changes the communication pattern, never the computation's inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cooperative import (
    CoopCapacityPlan,
    SimExecutor,
    build_cooperative_minibatch,
    plan_stats,
    redistribute,
)
from repro.core.graph import INVALID
from repro.core.partition import hash_partition
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler

P, B_LOCAL, L = 4, 64, 2
IM = np.iinfo(np.int32).max


@pytest.fixture(scope="module")
def coop_setup(small_graph):
    part = hash_partition(small_graph.num_vertices, P)
    owner = np.asarray(part.owner)
    rng_np = np.random.default_rng(0)
    seeds = np.full((P, B_LOCAL), IM, np.int32)
    for p in range(P):
        own = np.nonzero(owner == p)[0]
        seeds[p] = rng_np.choice(own, size=B_LOCAL, replace=False)
    caps = CoopCapacityPlan.geometric(
        B_LOCAL, L, fanout=5, num_vertices=small_graph.num_vertices, num_pes=P
    )
    ex = SimExecutor(P)
    sampler = make_sampler("labor0", fanout=5)
    mb = build_cooperative_minibatch(
        small_graph, sampler, part, jnp.asarray(seeds), DependentRNG(3, 1, 0),
        L, caps, ex,
    )
    return part, owner, caps, ex, mb


def test_ownership_invariant(coop_setup, small_graph):
    """Every owned frontier S_p^l contains only vertices owned by p."""
    _, owner, _, _, mb = coop_setup
    for layer in mb.layers:
        s = np.asarray(layer.seeds)
        for p in range(P):
            valid = s[p][s[p] != IM]
            assert (owner[valid] == p).all()
    inp = np.asarray(mb.input_ids)
    for p in range(P):
        valid = inp[p][inp[p] != IM]
        assert (owner[valid] == p).all()


def test_redistribute_exact(coop_setup, small_graph):
    """H~ rows match a direct feature lookup of the tilde ids."""
    _, _, caps, ex, mb = coop_setup
    V, d = small_graph.num_vertices, 8
    feat = jnp.asarray(
        np.random.default_rng(1).standard_normal((V, d)).astype(np.float32)
    )
    for l in range(L):
        layer = mb.layers[l]
        cap_next = caps.caps[l + 1]

        def load(ids):
            h = feat[jnp.clip(ids, 0, V - 1)]
            return jnp.where((ids != INVALID)[:, None], h, 0.0)

        # owned embeddings for S^{l+1}
        next_ids = (
            mb.layers[l + 1].seeds if l + 1 < L else mb.input_ids
        )
        H = jax.vmap(load)(next_ids)
        Ht = redistribute(ex, layer, H, caps.tilde_caps[l])
        tid = np.asarray(layer.tilde_ids)
        Ht_np, feat_np = np.asarray(Ht), np.asarray(feat)
        for p in range(P):
            valid = tid[p] != IM
            np.testing.assert_array_equal(
                Ht_np[p][valid], feat_np[tid[p][valid]]
            )


def test_local_indices_resolve_into_tilde(coop_setup):
    _, _, _, _, mb = coop_setup
    for layer in mb.layers:
        tid = np.asarray(layer.tilde_ids)
        nbr_idx = np.asarray(layer.nbr_idx)
        self_idx = np.asarray(layer.self_idx)
        seeds = np.asarray(layer.seeds)
        for p in range(P):
            valid = seeds[p] != IM
            # every valid seed resolves to itself inside tilde
            si = self_idx[p][valid]
            assert (si >= 0).all()
            np.testing.assert_array_equal(tid[p][si], seeds[p][valid])
            m = np.asarray(layer.mask[p])
            assert (nbr_idx[p][m] >= 0).all()


def test_gradient_flows_through_exchange(coop_setup, small_graph):
    _, _, caps, ex, mb = coop_setup
    V, d = small_graph.num_vertices, 4
    feat = jnp.ones((V, d), jnp.float32)
    layer = mb.layers[L - 1]

    def loss(H):
        Ht = redistribute(ex, layer, H, caps.tilde_caps[L - 1])
        return jnp.sum(Ht ** 2)

    H = jax.vmap(lambda ids: feat[jnp.clip(ids, 0, V - 1)])(mb.input_ids)
    g = jax.grad(loss)(H)
    assert float(jnp.linalg.norm(g)) > 0
    assert not bool(jnp.any(jnp.isnan(g)))


def test_plan_stats_keys(coop_setup):
    _, _, _, ex, mb = coop_setup
    stats = plan_stats(mb, ex)
    for k in ("S0", "E0", "tilde1", "comm1", "inputs"):
        assert k in stats and stats[k] >= 0


def test_cooperative_dedup_beats_independent(small_graph):
    """Global unique inputs of the coop batch <= sum of per-PE
    independent batches at equal global batch size (the paper's premise).
    """
    from repro.core.minibatch import CapacityPlan, build_minibatch

    part = hash_partition(small_graph.num_vertices, P)
    owner = np.asarray(part.owner)
    rng_np = np.random.default_rng(5)
    seeds = np.full((P, B_LOCAL), IM, np.int32)
    for p in range(P):
        own = np.nonzero(owner == p)[0]
        seeds[p] = rng_np.choice(own, size=B_LOCAL, replace=False)
    caps_c = CoopCapacityPlan.geometric(
        B_LOCAL, L, 5, small_graph.num_vertices, P
    )
    mb_c = build_cooperative_minibatch(
        small_graph, make_sampler("labor0", fanout=5), part,
        jnp.asarray(seeds), DependentRNG(3, 1, 0), L, caps_c, SimExecutor(P),
    )
    coop_inputs = int((np.asarray(mb_c.input_ids) != IM).sum())

    caps_i = CapacityPlan.geometric(B_LOCAL, L, 5, small_graph.num_vertices)
    indep_total = 0
    for p in range(P):
        mb_i = build_minibatch(
            small_graph, make_sampler("labor0", fanout=5),
            jnp.asarray(seeds[p]), DependentRNG(3, 1, 0), L, caps_i,
        )
        indep_total += int(mb_i.num_inputs)
    assert coop_inputs < indep_total
