"""Smoothed dependent RNG (A.7): uniformity + drift schedule."""
import jax.numpy as jnp
import numpy as np

from repro.core.rng import DependentRNG, RNGState


def _corr(a, b):
    return float(jnp.corrcoef(a, b)[0, 1])


def test_marginals_uniform_at_every_c():
    ids = jnp.arange(40_000)
    for step in (0, 1, 3, 7):
        r = DependentRNG(7, 8, step).vertex_uniform(ids)
        assert abs(float(r.mean()) - 0.5) < 0.01
        assert abs(float(r.std()) - np.sqrt(1 / 12)) < 0.01


def test_adjacent_steps_highly_correlated():
    ids = jnp.arange(2_000)
    r0 = DependentRNG(7, 64, 0).vertex_uniform(ids)
    r1 = DependentRNG(7, 64, 1).vertex_uniform(ids)
    assert _corr(r0, r1) > 0.99


def test_window_boundary_decorrelates():
    ids = jnp.arange(2_000)
    r0 = DependentRNG(7, 64, 0).vertex_uniform(ids)
    r64 = DependentRNG(7, 64, 64).vertex_uniform(ids)
    assert abs(_corr(r0, r64)) < 0.1


def test_kappa_one_is_independent_across_steps():
    ids = jnp.arange(2_000)
    r0 = DependentRNG(7, 1, 0).vertex_uniform(ids)
    r1 = DependentRNG(7, 1, 1).vertex_uniform(ids)
    assert abs(_corr(r0, r1)) < 0.1


def test_infinite_kappa_is_static():
    ids = jnp.arange(100)
    r0 = DependentRNG(7, None, 0).vertex_uniform(ids)
    r9 = DependentRNG(7, None, 999).vertex_uniform(ids)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r9))


def test_edge_uniform_order_sensitive():
    t = jnp.asarray([1, 2, 3])
    s = jnp.asarray([4, 5, 6])
    r1 = DependentRNG(0, 1, 0).edge_uniform(t, s)
    r2 = DependentRNG(0, 1, 0).edge_uniform(s, t)
    assert not np.allclose(np.asarray(r1), np.asarray(r2))


def test_dynamic_state_matches_host_state():
    """state_at with traced step == state_at with python step."""
    import jax

    rng = DependentRNG(11, 4)
    ids = jnp.arange(64)

    def f(step):
        return rng.state_at(step).vertex_uniform(ids)

    out_traced = jax.jit(f)(jnp.int32(5))
    out_host = rng.state_at(5).vertex_uniform(ids)
    np.testing.assert_allclose(np.asarray(out_traced), np.asarray(out_host), rtol=1e-6)
