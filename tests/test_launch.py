"""Launch-layer units: HLO cost parser, sharding rules, specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import model_flops
from repro.launch.specs import SHAPES, batch_specs, shape_applicable


def test_hlo_parser_counts_loop_iterations():
    """A jitted scan's dots must be multiplied by the trip count."""

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = analyze_hlo(compiled.as_text())
    expect = 7 * 2 * 64 * 64 * 64
    assert abs(costs.dot_flops - expect) / expect < 0.01, costs.dot_flops


def test_hlo_parser_finds_unrolled_dots():
    def f(x, w):
        for _ in range(3):
            x = x @ w
        return x

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, x).compile()
    costs = analyze_hlo(compiled.as_text())
    expect = 3 * 2 * 32**3
    assert abs(costs.dot_flops - expect) / expect < 0.01


def test_batch_specs_shapes():
    from repro.configs import get_config

    cfg = get_config("internvl2-26b")
    spec = SHAPES["train_4k"]
    b = batch_specs(cfg, spec)
    # vlm: 64 prefix patch embeddings + text fills the rest of seq_len
    assert b["tokens"].shape == (256, 4096 - 64)
    assert b["prefix_embeds"].shape == (256, 64, cfg.d_model)

    cfg_w = get_config("whisper-tiny")
    bw = batch_specs(cfg_w, SHAPES["prefill_32k"])
    assert bw["enc_out"].shape == (32, cfg_w.enc_len, cfg_w.d_model)


def test_shape_applicability_matrix():
    from repro.configs import ALL_ARCHS, get_config

    long_ok = {a for a in ALL_ARCHS if shape_applicable(get_config(a), "long_500k")[0]}
    assert long_ok == {"mamba2-2.7b", "hymba-1.5b", "gemma2-2b", "gemma3-27b"}
    for a in ALL_ARCHS:  # every other shape applies to every arch
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), s)[0]


def test_model_flops_formulas():
    from repro.configs import get_config
    from repro.models.transformer.config import active_param_count

    cfg = get_config("granite-3-8b")
    n = active_param_count(cfg)
    t = model_flops(cfg, SHAPES["train_4k"], n)
    assert t == 6.0 * n * 256 * 4096
    d = model_flops(cfg, SHAPES["decode_32k"], n)
    assert d == 2.0 * n * 128


def test_param_sharding_rules_small_mesh():
    """Divisibility gating: shards what divides, replicates what doesn't."""
    from repro.launch.shardings import param_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {
        "embed": jnp.zeros((64, 8)),
        "blocks": [{"attn": {"wq": jnp.zeros((2, 8, 16))},
                    "norm1": jnp.zeros((2, 8))}],
        "tail": [],
        "final_norm": jnp.zeros((8,)),
    }
    sh = param_shardings(mesh, params)
    assert sh["embed"].spec == P("model", None)
    assert sh["blocks"][0]["attn"]["wq"].spec == P(None, None, "model")
    assert sh["blocks"][0]["norm1"].spec == P(None, None)
