"""Multi-device cooperative execution: ShardRunner vs SimExecutor parity.

The parity contract (docs/cooperative_execution.md): on identical
κ-scheduled traces, the shard_map path must produce **bit-identical**
integer plan state (seeds, indices, masks, bucket slots) and
reduction-order-equal floats (loss/gradients within float32 tolerance of
the single-device reduction).

Everything device-related runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the main test
session keeps its single device (per the launch brief); one subprocess
covers plan parity, loss/grad parity, a train step, and the all-to-all
conservation invariants to amortize startup.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np

    from repro.core.graph import INVALID
    from repro.data import rmat_graph
    from repro.data.synthetic import SyntheticGraphDataset
    from repro.engine import EngineConfig, MinibatchEngine
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.train.loop import TrainConfig, make_loss_fn, train_gnn
    from repro.train.optim import adam_init, adam_update

    P, B, L = 4, 16, 2
    g = rmat_graph(scale=10, edge_factor=8, max_degree=32, seed=0)
    ds = SyntheticGraphDataset(g, feature_dim=16, num_classes=8, seed=0)
    gnn_cfg = GNNConfig(model="gcn", num_layers=L, in_dim=16, hidden_dim=32,
                        num_classes=8)
    params = init_gnn(jax.random.PRNGKey(0), gnn_cfg)

    def engines(schedule, kappa, partition):
        cfg = EngineConfig(
            mode="cooperative", num_pes=P, local_batch=B, num_layers=L,
            sampler="labor0", fanout=5, schedule=schedule, kappa=kappa,
            partition=partition, seed=7,
        )
        sim = MinibatchEngine.from_config(g, cfg, dataset=ds)
        sh = MinibatchEngine.from_config(
            g, dataclasses.replace(cfg, executor="shard"), dataset=ds)
        return sim, sh

    # ---- 1. plan bit-parity across kappa schedules -----------------------
    for schedule, kappa, partition in [
        ("smoothed", 3, "hash"), ("nested", 2, "degree"),
    ]:
        sim, sh = engines(schedule, kappa, partition)
        for step in range(3):
            leaves_sim = jax.tree.leaves(sim.plan_at(step))
            leaves_sh = jax.tree.leaves(sh.plan_at(step))
            assert len(leaves_sim) == len(leaves_sh)
            for a, b in zip(leaves_sim, leaves_sh):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("PLAN_PARITY_OK")

    # ---- 2. loss + psum-synced grads match the vmap oracle ---------------
    sim, sh = engines("smoothed", 3, "degree")
    lg_sim = jax.value_and_grad(make_loss_fn(sim, gnn_cfg, sim.store, ds.labels))
    lg_sh = sh.shard_runner.make_loss_and_grad(gnn_cfg, sh.store.features,
                                               ds.labels)
    for step in range(4):
        l1, g1 = lg_sim(params, jnp.int32(step))
        l2, g2 = lg_sh(params, jnp.int32(step))
        np.testing.assert_allclose(float(l1), float(l2), rtol=5e-6)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-6, rtol=1e-4)
    print("LOSS_GRAD_PARITY_OK")

    # ---- 3. one adam step stays in lockstep ------------------------------
    def one_step(lg):
        opt = adam_init(params)
        loss, grads = lg(params, jnp.int32(0))
        new_params, _ = adam_update(params, grads, opt, lr=1e-3)
        return new_params
    for a, b in zip(jax.tree.leaves(one_step(lg_sim)),
                    jax.tree.leaves(one_step(lg_sh))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    print("TRAIN_STEP_PARITY_OK")

    # ---- 4. all-to-all conservation under shard_map ----------------------
    # Stacked layout: slot_to_tilde[p, q, s] >= 0 means PE p requested a
    # q-owned vertex at bucket slot s; req_idx[q, p, s] >= 0 means owner q
    # resolved that same slot after the wire exchange.  Conservation:
    # rows sent == rows received == rows resolved, elementwise.
    plan = sh.plan_at(0)
    owner = np.asarray(sh.part.owner)
    for l, layer in enumerate(plan.layers):
        sent = np.asarray(layer.slot_to_tilde) >= 0      # (P, Q, cap_b)
        resolved = np.asarray(layer.req_idx) >= 0        # (Q, P, cap_b)
        np.testing.assert_array_equal(sent, resolved.swapaxes(0, 1))
        # every id in PE p's bucket q really is owned by q (keyed by
        # ownership), and resolves to that id's row in q's next frontier
        tilde = np.asarray(layer.tilde_ids)              # (P, cap_t)
        s2t = np.asarray(layer.slot_to_tilde)
        for p in range(P):
            for q in range(P):
                ids = tilde[p][s2t[p, q][sent[p, q]]]
                assert (owner[ids] == q).all(), (l, p, q)
    # rows gathered: redistributing all-ones embeddings must deliver one
    # nonzero row per filled tilde slot, none elsewhere
    from repro.core.cooperative import SimExecutor, redistribute
    sim_plan = sim.plan_at(0)
    ones = jnp.ones(np.asarray(sim_plan.input_ids).shape + (4,), jnp.float32)
    Ht = redistribute(SimExecutor(P), sim_plan.layers[L - 1], ones,
                      sim.caps.tilde_caps[L - 1])
    got = np.asarray(jnp.any(Ht != 0, axis=-1))
    want = np.zeros_like(got)
    s2t = np.asarray(sim_plan.layers[L - 1].slot_to_tilde)
    for p in range(P):
        want[p][s2t[p][s2t[p] >= 0]] = True
    np.testing.assert_array_equal(got, want)
    print("A2A_CONSERVATION_OK")

    # ---- 5. train_gnn end to end: executor is a config flag --------------
    losses = {}
    for ex in ("sim", "shard"):
        tc = TrainConfig(mode="cooperative", num_pes=P, local_batch=B,
                         num_steps=4, schedule="smoothed", kappa=3,
                         partition="degree", executor=ex, eval_every=0)
        losses[ex] = train_gnn(ds, gnn_cfg, tc).losses
    np.testing.assert_allclose(losses["sim"], losses["shard"], rtol=1e-5)
    print("TRAIN_GNN_PARITY_OK")
    """
)

_MARKERS = [
    "PLAN_PARITY_OK",
    "LOSS_GRAD_PARITY_OK",
    "TRAIN_STEP_PARITY_OK",
    "A2A_CONSERVATION_OK",
    "TRAIN_GNN_PARITY_OK",
]


@pytest.mark.slow
def test_shard_runner_parity_and_conservation():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        timeout=560,
    )
    for marker in _MARKERS:
        assert marker in out.stdout, (marker, out.stdout, out.stderr[-3000:])


def test_shard_runner_needs_enough_devices(small_graph):
    """Single-device session: the mesh constructor must explain the fix."""
    from repro.engine import EngineConfig, MinibatchEngine

    eng = MinibatchEngine.from_config(
        small_graph,
        EngineConfig(mode="cooperative", num_pes=4, local_batch=8,
                     num_layers=2, executor="shard"),
    )
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        eng.shard_runner

    with pytest.raises(ValueError, match="plan_at"):
        eng.build_plan(eng.seed_batch(0))


def test_shard_runner_rejects_independent(small_graph):
    from repro.engine import EngineConfig, MinibatchEngine
    from repro.engine.shard import ShardRunner

    eng = MinibatchEngine.from_config(
        small_graph,
        EngineConfig(mode="independent", num_pes=1, local_batch=8,
                     num_layers=2),
    )
    with pytest.raises(ValueError, match="cooperative"):
        ShardRunner.for_engine(eng)
