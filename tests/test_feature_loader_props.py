"""Property tests for ``FeatureStore`` fetch accounting.

Runs under the ``tests/_hypothesis_compat`` shim: with hypothesis
installed the ``@given`` tests fuzz the invariants; without it they skip
and the plain unit tests below still pin the same properties on fixed
inputs.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.feature_loader import FeatureStore
from repro.core.graph import INVALID
from tests._hypothesis_compat import given, settings, strategies as st

V, D = 64, 5


@pytest.fixture(scope="module")
def store():
    rng = np.random.default_rng(0)
    return FeatureStore(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32)))


ids_1d = st.lists(
    st.integers(min_value=0, max_value=V - 1), min_size=0, max_size=40
).map(lambda xs: np.asarray(xs, np.int32))
mask_positions = st.lists(
    st.integers(min_value=0, max_value=39), min_size=0, max_size=10
)


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------
@given(ids=ids_1d, masked=mask_positions)
@settings(max_examples=50, deadline=None)
def test_invalid_rows_gather_to_zero(ids, masked):
    rng = np.random.default_rng(1)
    store = FeatureStore(jnp.asarray(rng.normal(size=(V, D)).astype(np.float32)))
    ids = ids.copy()
    for p in masked:
        if p < len(ids):
            ids[p] = np.int32(INVALID)
    out = np.asarray(store.gather(jnp.asarray(ids)))
    assert out.shape == (len(ids), D)
    invalid = ids == np.int32(INVALID)
    assert np.all(out[invalid] == 0.0)
    valid_feats = np.asarray(store.features)[ids[~invalid]]
    assert np.array_equal(out[~invalid], valid_feats)


@given(ids=ids_1d, seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_count_fetched_permutation_and_padding_invariant(ids, seed):
    rng = np.random.default_rng(seed)
    store = FeatureStore(jnp.zeros((V, D), jnp.float32))
    base = store.count_fetched(ids)
    assert store.count_fetched(rng.permutation(ids)) == base
    padded = np.concatenate([ids, np.full(3, np.int32(INVALID))])
    assert store.count_fetched(rng.permutation(padded)) == base
    # duplicating entries never changes the unique-row fetch count
    assert store.count_fetched(np.concatenate([ids, ids])) == base


@given(
    rows=st.lists(ids_1d, min_size=1, max_size=4).filter(
        lambda rs: len({len(r) for r in rs}) == 1
    )
)
@settings(max_examples=50, deadline=None)
def test_duplicates_across_pes_nonnegative(rows):
    store = FeatureStore(jnp.zeros((V, D), jnp.float32))
    per_pe = np.stack(rows)
    dup = store.count_duplicates_across_pes(per_pe)
    assert dup >= 0
    # per-PE unique sum decomposes as global unique + duplicates
    assert store.count_fetched(per_pe) == dup + int(
        (np.unique(per_pe.ravel()) != INVALID).sum()
    )


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_disjoint_partitions_have_zero_duplicates(seed):
    rng = np.random.default_rng(seed)
    store = FeatureStore(jnp.zeros((V, D), jnp.float32))
    P = 4
    # ownership partition: row p gets only ids ≡ p (mod P)
    per_pe = np.stack(
        [rng.choice(V // P, 8, replace=False) * P + p for p in range(P)]
    )
    assert store.count_duplicates_across_pes(per_pe) == 0


# ---------------------------------------------------------------------------
# plain pins (always run, even without hypothesis)
# ---------------------------------------------------------------------------
def test_invalid_masking_fixed(store):
    ids = jnp.asarray([3, INVALID, 7], jnp.int32)
    out = np.asarray(store.gather(ids))
    assert np.all(out[1] == 0.0)
    assert np.array_equal(out[0], np.asarray(store.features)[3])
    assert np.array_equal(out[2], np.asarray(store.features)[7])


def test_count_fetched_fixed(store):
    ids = np.asarray([5, 5, 9, INVALID, 9, 2], np.int32)
    assert store.count_fetched(ids) == 3
    # 2-D counts per PE row, then sums
    assert store.count_fetched(np.stack([ids, ids])) == 6


def test_duplicates_fixed(store):
    per_pe = np.asarray([[1, 2, 3], [3, 4, 5]], np.int32)
    assert store.count_duplicates_across_pes(per_pe) == 1
    disjoint = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
    assert store.count_duplicates_across_pes(disjoint) == 0
