"""MinibatchEngine facade: parity with the kernel-layer builders.

The engine must be a *wiring* layer, not a reimplementation: independent
plans must equal ``build_minibatch`` bit-for-bit, cooperative plan stats
must match ``build_cooperative_minibatch`` under ``SimExecutor``, and
streams must be deterministic functions of the config.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cooperative import (
    CoopCapacityPlan,
    CoopMinibatch,
    SimExecutor,
    build_cooperative_minibatch,
    plan_stats,
)
from repro.core.graph import INVALID
from repro.core.minibatch import CapacityPlan, Minibatch, build_minibatch
from repro.core.partition import make_partition
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler
from repro.engine import EngineConfig, MinibatchEngine, Plan

L, B, FANOUT = 2, 32, 5


def _engine(graph, **kw):
    defaults = dict(
        mode="independent", num_pes=2, local_batch=B, num_layers=L,
        sampler="labor0", fanout=FANOUT, seed=3,
    )
    defaults.update(kw)
    return MinibatchEngine.from_config(graph, EngineConfig(**defaults))


def _assert_minibatch_equal(a: Minibatch, b: Minibatch):
    np.testing.assert_array_equal(np.asarray(a.input_ids), np.asarray(b.input_ids))
    np.testing.assert_array_equal(np.asarray(a.seed_ids), np.asarray(b.seed_ids))
    for la, lb in zip(a.layers, b.layers):
        for f in ("seeds", "self_idx", "nbr_idx", "mask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(la, f)), np.asarray(getattr(lb, f)), err_msg=f
            )


def test_plans_satisfy_protocol(small_graph):
    eng = _engine(small_graph)
    plan = eng.build_plan(eng.seed_batch(0))
    assert isinstance(plan, Plan)
    ceng = _engine(small_graph, mode="cooperative", num_pes=4)
    cplan = ceng.build_plan(ceng.seed_batch(0))
    assert isinstance(cplan, Plan)
    assert isinstance(cplan, CoopMinibatch)


def test_independent_engine_matches_build_minibatch(small_graph):
    """1-D seeds: the engine IS build_minibatch, bit for bit."""
    eng = _engine(small_graph, num_pes=1)
    seeds = eng.seed_batch(0)[0]
    plan = eng.build_plan(seeds, step=0)
    caps = CapacityPlan.geometric(B, L, FANOUT, small_graph.num_vertices)
    ref = build_minibatch(
        small_graph, make_sampler("labor0", fanout=FANOUT),
        jnp.asarray(seeds, jnp.int32), DependentRNG(3, 1, 0), L, caps,
    )
    _assert_minibatch_equal(plan, ref)


def test_independent_stacked_rows_match_solo_builds(small_graph):
    """(P, b) seeds: every vmapped row equals its standalone build."""
    eng = _engine(small_graph, num_pes=3)
    seeds = eng.seed_batch(5)
    plan = eng.build_plan(seeds, step=5)
    caps = CapacityPlan.geometric(B, L, FANOUT, small_graph.num_vertices)
    sampler = make_sampler("labor0", fanout=FANOUT)
    for p in range(3):
        ref = build_minibatch(
            small_graph, sampler, jnp.asarray(seeds[p], jnp.int32),
            DependentRNG(3, 1, 5), L, caps,
        )
        np.testing.assert_array_equal(np.asarray(plan.input_ids)[p],
                                      np.asarray(ref.input_ids))
        np.testing.assert_array_equal(np.asarray(plan.seed_ids)[p],
                                      np.asarray(ref.seed_ids))
        for la, lb in zip(plan.layers, ref.layers):
            for f in ("seeds", "self_idx", "nbr_idx", "mask"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(la, f))[p], np.asarray(getattr(lb, f)),
                    err_msg=f"PE {p} field {f}",
                )


@pytest.mark.parametrize("P", [1, 4])
def test_cooperative_engine_matches_direct_builder(small_graph, P):
    """Engine cooperative plan_stats == direct builder under SimExecutor."""
    eng = _engine(small_graph, mode="cooperative", num_pes=P)
    seeds = eng.seed_batch(0)
    stats = eng.build_plan(seeds, step=0).stats()

    caps = CoopCapacityPlan.geometric(B, L, FANOUT, small_graph.num_vertices, P)
    part = make_partition("hash", small_graph, P, seed=3)
    ex = SimExecutor(P)
    ref = build_cooperative_minibatch(
        small_graph, make_sampler("labor0", fanout=FANOUT), part,
        jnp.asarray(seeds), DependentRNG(3, 1, 0), L, caps, ex,
    )
    assert stats == plan_stats(ref, ex)


def test_cooperative_seed_rows_are_owned(small_graph):
    eng = _engine(small_graph, mode="cooperative", num_pes=4)
    owner = np.asarray(eng.part.owner)
    seeds = eng.seed_batch(7)
    for p in range(4):
        valid = seeds[p][seeds[p] != np.int32(INVALID)]
        assert (owner[valid] == p).all()


def test_smoothed_stream_determinism(small_graph):
    """Same config => identical (seeds, rng, input_ids) at every step."""
    mk = lambda: _engine(
        small_graph, num_pes=2, schedule="smoothed", kappa=4, seed=13
    ).stream(num_steps=6)
    a, b = list(mk()), list(mk())
    assert [x.step for x in a] == list(range(6))
    for ia, ib in zip(a, b):
        assert ia.rng == DependentRNG(13, 4, ia.step)
        np.testing.assert_array_equal(ia.seeds, ib.seeds)
        np.testing.assert_array_equal(
            np.asarray(ia.plan.input_ids), np.asarray(ib.plan.input_ids)
        )


def test_smoothed_stream_drifts_within_window(small_graph):
    """Consecutive in-window plans overlap more than cross-window plans
    (the locality that drives Fig 5a)."""
    eng = _engine(
        small_graph, num_pes=1, schedule="smoothed", kappa=64, seed=0
    )
    seeds = eng.seed_batch(0)[0]
    ids0 = np.asarray(eng.build_plan(seeds, step=0).input_ids)
    ids1 = np.asarray(eng.build_plan(seeds, step=1).input_ids)  # same window
    eng_iid = _engine(small_graph, num_pes=1, schedule="iid", seed=0)
    ids_far = np.asarray(eng_iid.build_plan(seeds, step=1).input_ids)
    j = lambda x, y: len(np.intersect1d(x[x != INVALID], y[y != INVALID])) / max(
        len(np.union1d(x[x != INVALID], y[y != INVALID])), 1
    )
    assert j(ids0, ids1) > j(ids0, ids_far)


def test_rng_state_matches_host_schedule(small_graph):
    """Traced rng_state(step) == host rng_at(step).state for all schedules."""
    for schedule, kappa in (("iid", None), ("smoothed", 8), ("nested", 4)):
        eng = _engine(small_graph, schedule=schedule, kappa=kappa or 1)
        for step in (0, 3, 9):
            traced = eng.rng_state(jnp.int32(step))
            host = eng.rng_at(step).state
            assert int(traced.z1) == int(host.z1), (schedule, step)
            assert int(traced.z2) == int(host.z2), (schedule, step)
            assert float(traced.c) == pytest.approx(float(host.c)), (schedule, step)


def test_nested_subbatches_partition_group(small_graph):
    """Within one group, the κ sub-batches are disjoint; the group pool
    (and its frozen RNG) is shared — §3.2 nesting."""
    eng = _engine(small_graph, num_pes=1, schedule="nested", kappa=3, seed=5)
    rows = [eng.seed_batch(s)[0] for s in range(3)]
    valid = [r[r != np.int32(INVALID)] for r in rows]
    allv = np.concatenate(valid)
    assert len(np.unique(allv)) == len(allv)  # disjoint within the group
    assert eng.rng_at(0) == eng.rng_at(2)     # frozen group RNG
    assert eng.rng_at(0) != eng.rng_at(3)     # refreshed next group
