"""Docs stay anchored to code: every pointer in docs/ + README resolves.

``tools/check_docs.py`` is the single source of truth (the docs-check
CI job runs it directly); these tests keep it honest from inside
tier-1 — both directions: the real docs pass, and a planted dead
pointer is actually caught.
"""
from __future__ import annotations

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402

DOCS = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]


def test_docs_exist():
    names = {p.name for p in DOCS}
    assert {"architecture.md", "cooperative_execution.md", "kernels.md",
            "benchmarks.md", "README.md"} <= names


def test_all_pointers_resolve(capsys):
    assert check_docs.main([]) == 0
    out = capsys.readouterr().out
    assert "pointers resolve" in out


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_each_doc_clean(doc):
    assert check_docs.check_file(doc, {}) == []


def test_dead_symbol_is_caught(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text(
        "see `src/repro/engine/shard.py:NoSuchSymbol` and\n"
        "`src/repro/engine/nonexistent_module.py:ShardRunner` and\n"
        "`docs/never_written.md` for details\n"
    )
    dead = check_docs.check_file(bad, {})
    reasons = {tok: reason for _, tok, reason in dead}
    assert reasons["src/repro/engine/shard.py:NoSuchSymbol"] == "symbol missing"
    assert reasons["src/repro/engine/nonexistent_module.py:ShardRunner"] == "file missing"
    assert reasons["docs/never_written.md"] == "path missing"


def test_live_symbol_forms_resolve(tmp_path):
    ok = tmp_path / "ok.md"
    ok.write_text(
        "`src/repro/engine/shard.py:ShardRunner` plus method form\n"
        "`src/repro/engine/shard.py:ShardRunner.make_loss_and_grad` plus\n"
        "constant `src/repro/core/graph.py:INVALID`; shell commands like\n"
        "`python -m pytest -q` and bare names like `BENCH_plan_build.json`\n"
        "are ignored\n"
    )
    assert check_docs.check_file(ok, {}) == []
