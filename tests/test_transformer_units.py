"""Transformer building-block unit tests + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models.transformer.attention import (
    _banded_local_attention,
    _flash_attention,
)
from repro.models.transformer.modules import rms_norm, softcap
from repro.models.transformer.moe import init_moe, moe_apply
from repro.models.transformer.ssm import init_ssm, ssm_train

R = np.random.default_rng(0)


def _naive_attention(q, k, v, window, cap):
    B, S, H, hd = q.shape
    s = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    if cap:
        s = cap * np.tanh(s / cap)
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = np.where(ok[None, None], s, -1e9)
    w = np.exp(s - s.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", w, v)


@pytest.mark.parametrize("window,cap", [(None, None), (16, None), (None, 30.0)])
def test_flash_attention_matches_naive(window, cap):
    B, S, H, hd = 2, 64, 2, 16
    q = R.standard_normal((B, S, H, hd)).astype(np.float32)
    k = R.standard_normal((B, S, H, hd)).astype(np.float32)
    v = R.standard_normal((B, S, H, hd)).astype(np.float32)
    out = _flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), window, cap, block_k=16
    )
    ref = _naive_attention(q, k, v, window, cap)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_banded_local_matches_naive():
    B, S, H, hd, W = 1, 96, 2, 8, 16
    q = R.standard_normal((B, S, H, hd)).astype(np.float32)
    k = R.standard_normal((B, S, H, hd)).astype(np.float32)
    v = R.standard_normal((B, S, H, hd)).astype(np.float32)
    out = _banded_local_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), W, None
    )
    ref = _naive_attention(q, k, v, W, None)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_ssm_causality():
    """Perturbing position t must not change outputs before t."""
    cfg = get_config("mamba2-2.7b").reduced(ssm_chunk=8)
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    u = jnp.asarray(R.standard_normal((1, 32, cfg.d_model)).astype(np.float32))
    y0 = ssm_train(p, cfg, u)
    u2 = u.at[0, 20, :].add(1.0)
    y1 = ssm_train(p, cfg, u2)
    np.testing.assert_allclose(
        np.asarray(y0)[0, :20], np.asarray(y1)[0, :20], atol=1e-5
    )
    assert float(jnp.abs(y0[0, 20:] - y1[0, 20:]).max()) > 1e-4


def test_ssm_chunk_invariance():
    """Chunk size is an implementation detail: outputs must not change."""
    cfg8 = get_config("mamba2-2.7b").reduced(ssm_chunk=8)
    cfg16 = get_config("mamba2-2.7b").reduced(ssm_chunk=16)
    p = init_ssm(jax.random.PRNGKey(0), cfg8)
    u = jnp.asarray(R.standard_normal((2, 32, cfg8.d_model)).astype(np.float32))
    y8 = ssm_train(p, cfg8, u)
    y16 = ssm_train(p, cfg16, u)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=2e-4)


def test_moe_group_invariance_when_capacity_loose():
    """With loose capacity, grouped routing == ungrouped routing."""
    cfg1 = get_config("grok-1-314b").reduced(moe_capacity_factor=8.0)
    cfg2 = get_config("grok-1-314b").reduced(moe_capacity_factor=8.0)
    cfg2 = type(cfg2).__call__ if False else cfg2
    import dataclasses

    cfg2 = dataclasses.replace(cfg2, moe_groups=2)
    p = init_moe(jax.random.PRNGKey(0), cfg1)
    x = jnp.asarray(R.standard_normal((4, 8, cfg1.d_model)).astype(np.float32))
    y1, _ = moe_apply(p, cfg1, x)
    y2, _ = moe_apply(p, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_moe_capacity_drops_tokens():
    import dataclasses

    cfg = get_config("grok-1-314b").reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(R.standard_normal((2, 16, cfg.d_model)).astype(np.float32))
    y, aux = moe_apply(p, cfg, x)
    # some rows get zero expert output (dropped), none are NaN
    norms = np.linalg.norm(np.asarray(y).reshape(-1, cfg.d_model), axis=1)
    assert (norms == 0).any()
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(float(aux))


@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=1.0, max_value=100.0))
def test_softcap_bounded(cap):
    x = jnp.linspace(-1e4, 1e4, 101)
    y = np.asarray(softcap(x, cap))
    assert (np.abs(y) <= cap + 1e-3).all()
    # approximately identity near zero
    assert abs(float(softcap(jnp.asarray(cap / 100), cap)) - cap / 100) < cap * 1e-3


def test_rms_norm_scale_invariance():
    x = jnp.asarray(R.standard_normal((4, 32)).astype(np.float32))
    s = jnp.zeros((32,))
    y1 = rms_norm(x, s)
    y2 = rms_norm(3.0 * x, s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_cooperative_embed_exact():
    """DESIGN.md §4 transfer: dedup'd vocab gather == plain lookup,
    forward and backward (the paper's cooperative feature loading applied
    to token embeddings)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.transformer import init_lm
    from repro.models.transformer.model import forward_hidden

    cfg = get_config("granite-3-8b").reduced(vocab_size=64)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(R.integers(0, 64, (4, 40)), jnp.int32)
    cfg2 = dataclasses.replace(cfg, cooperative_embed=True)
    h1, _ = forward_hidden(params, cfg, toks)
    h2, _ = forward_hidden(params, cfg2, toks)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))

    def loss(p, c):
        return jnp.sum(forward_hidden(p, c, toks)[0] ** 2)

    g1 = jax.grad(loss)(params, cfg)["embed"]
    g2 = jax.grad(loss)(params, cfg2)["embed"]
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
