"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of its family
(2 layers, d_model <= 128, <= 4 experts) and runs one forward/train step
plus one decode step on CPU, asserting output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.launch.steps import lm_loss, make_serve_step, make_train_step
from repro.models.transformer import (
    forward_train,
    init_decode_state,
    init_lm,
)
from repro.train.optim import adam_init

B, S = 2, 32


def _batch(cfg, rng):
    s_text = S - cfg.num_prefix_tokens
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text)), jnp.int32),
    }
    if cfg.num_prefix_tokens:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["enc_out"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_arch_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 128
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    rng = np.random.default_rng(0)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = forward_train(
        params, cfg, batch["tokens"], batch.get("prefix_embeds"),
        batch.get("enc_out"),
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one decode step
    state = init_decode_state(cfg, B, 64)
    if cfg.enc_dec:
        state["enc_out"] = batch["enc_out"]
    serve = make_serve_step(cfg)
    lg, state = serve(params, state, batch["tokens"][:, :1])
    assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))
    assert int(state["pos"]) == 1


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-2.7b", "grok-1-314b"])
def test_reduced_arch_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1)
    params = init_lm(jax.random.PRNGKey(1), cfg)
    opt = adam_init(params)
    step = make_train_step(cfg, lr=1e-3)
    batch = _batch(cfg, rng)
    l0 = float(lm_loss(cfg, params, batch))
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
    l1 = float(lm_loss(cfg, params, batch))
    assert np.isfinite(l0) and np.isfinite(l1)
    assert l1 < l0  # overfits a fixed batch within a few steps


@pytest.mark.parametrize(
    "arch", ["mamba2-2.7b", "hymba-1.5b", "gemma2-2b", "gemma3-27b"]
)
def test_train_decode_consistency(arch):
    """Sequential decode reproduces teacher-forced logits exactly."""
    kw = dict(ssm_chunk=8, window=8)
    cfg = get_config(arch).reduced(**kw)
    params = init_lm(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)
    logits, _ = forward_train(params, cfg, toks)
    state = init_decode_state(cfg, B, 16)
    outs = []
    for t in range(16):
        lg, state = (make_serve_step(cfg))(params, state, toks[:, t : t + 1])
        outs.append(lg)
    err = float(jnp.abs(logits - jnp.stack(outs, 1)).max())
    assert err < 3e-3, err


@pytest.mark.parametrize(
    "arch", ["mamba2-2.7b", "hymba-1.5b", "gemma2-2b"]
)
def test_prefill_decode_bit_identical_to_stepping(arch):
    """Batched prefill == stepping the decoder token by token, exactly.

    Pins the ``examples/serve_lm.py`` prefill path exactly as the
    example runs it (both halves jitted): ``prefill_decode`` scans the
    same per-token decode step, so the final logits, the decode state,
    and every greedy token that follows must be bit-identical to
    stepping the jitted ``serve_step`` over the prompt — not
    approximately equal.
    """
    from repro.models.transformer import prefill_decode

    cfg = get_config(arch).reduced(ssm_chunk=8, window=8)
    params = init_lm(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    S0, new = 12, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S0)), jnp.int32)
    serve = jax.jit(make_serve_step(cfg))
    prefill = jax.jit(lambda p, st, t: prefill_decode(p, cfg, st, t))

    def greedy(logits, state, n):
        out = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n):
            out.append(np.asarray(tok)[:, 0])
            logits, state = serve(params, state, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return np.stack(out, 1)

    state_a = init_decode_state(cfg, B, S0 + new)
    logits_a, state_a = prefill(params, state_a, toks)

    state_b = init_decode_state(cfg, B, S0 + new)
    logits_b = None
    for t in range(S0):
        logits_b, state_b = serve(params, state_b, toks[:, t : t + 1])

    assert np.array_equal(np.asarray(logits_a), np.asarray(logits_b))
    for la, lb in zip(jax.tree_util.tree_leaves(state_a),
                      jax.tree_util.tree_leaves(state_b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    assert np.array_equal(greedy(logits_a, state_a, new),
                          greedy(logits_b, state_b, new))


def test_param_counts_match_published():
    """Sanity anchor: total params land near the published sizes."""
    from repro.models.transformer.config import active_param_count, param_count

    expect = {
        "mamba2-2.7b": 2.8e9,
        "granite-3-8b": 8.2e9,
        "gemma2-2b": 2.6e9,
        "nemotron-4-15b": 15.6e9,
        "gemma3-27b": 27e9,
        "hymba-1.5b": 1.5e9,
        "grok-1-314b": 316e9,
    }
    for arch, n in expect.items():
        got = param_count(get_config(arch))
        assert abs(got - n) / n < 0.12, (arch, got, n)
    assert active_param_count(get_config("grok-1-314b")) < 100e9


def test_long_context_skip_policy():
    from repro.launch.specs import shape_applicable

    ok, _ = shape_applicable(get_config("mamba2-2.7b"), "long_500k")
    assert ok
    ok, why = shape_applicable(get_config("granite-3-8b"), "long_500k")
    assert not ok and "full-attention" in why
