"""Empirical validation of Theorems 3.1 / 3.2 / 3.3 (paper §4.1)."""
import pytest

from repro.core.samplers import make_sampler
from repro.core.theory import (
    is_concave,
    is_monotone_nonincreasing,
    measure_density_curve,
    measure_work_curve,
)

BATCHES = [32, 64, 128, 256, 512]


@pytest.mark.parametrize("name", ["ns", "labor0", "labor*"])
def test_work_monotonicity_thm31(small_graph, name):
    """E[|S^L|]/|S^0| nonincreasing in batch size."""
    curve = measure_work_curve(
        small_graph, make_sampler(name, fanout=5), BATCHES,
        num_layers=2, trials=6, fanout_for_caps=5,
    )
    assert is_monotone_nonincreasing(curve.work_per_seed, tol=0.05), (
        name, curve.work_per_seed,
    )


@pytest.mark.parametrize("name", ["ns", "labor0"])
def test_subgraph_concavity_thm32(small_graph, name):
    """E[|S^L|] concave in batch size."""
    curve = measure_work_curve(
        small_graph, make_sampler(name, fanout=5), BATCHES,
        num_layers=2, trials=6, fanout_for_caps=5,
    )
    assert is_concave(curve.batch_sizes, curve.expected_sl, tol=0.1), (
        name, curve.expected_sl,
    )


def test_density_nondecreasing_thm33(small_graph):
    bs, density = measure_density_curve(small_graph, BATCHES, trials=6)
    assert all(b >= a * 0.95 for a, b in zip(density, density[1:])), density
