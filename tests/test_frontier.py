"""Padded set-ops: property-based (hypothesis) + unit tests."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import frontier
from repro.core.graph import INVALID

ids_strategy = st.lists(
    st.integers(min_value=0, max_value=500), min_size=0, max_size=64
)


@settings(max_examples=30, deadline=None)
@given(ids_strategy)
def test_unique_padded_matches_numpy(ids):
    ids_np = np.asarray(ids or [0], dtype=np.int32)
    cap = 128
    out = np.asarray(frontier.unique_padded(jnp.asarray(ids_np), cap))
    valid = out[out != INVALID]
    expect = np.unique(ids_np)
    np.testing.assert_array_equal(valid, expect)
    # sorted, padding at the end
    assert (np.sort(out) == out).all()


@settings(max_examples=30, deadline=None)
@given(ids_strategy, ids_strategy)
def test_union_is_set_union(a, b):
    a_np = np.asarray(a or [1], dtype=np.int32)
    b_np = np.asarray(b or [2], dtype=np.int32)
    out = np.asarray(
        frontier.union_padded(jnp.asarray(a_np), jnp.asarray(b_np), 256)
    )
    valid = out[out != INVALID]
    np.testing.assert_array_equal(valid, np.union1d(a_np, b_np))


@settings(max_examples=30, deadline=None)
@given(ids_strategy)
def test_lookup_inverts_membership(ids):
    ids_np = np.unique(np.asarray(ids or [3], dtype=np.int32))
    table = frontier.pad_to(jnp.asarray(ids_np), 128)
    pos = np.asarray(frontier.lookup(table, jnp.asarray(ids_np)))
    assert (pos >= 0).all()
    np.testing.assert_array_equal(np.asarray(table)[pos], ids_np)
    # absent ids -> -1
    absent = jnp.asarray([1001, 1002], jnp.int32)
    assert (np.asarray(frontier.lookup(table, absent)) == -1).all()


def test_lookup_invalid_is_minus_one():
    table = frontier.pad_to(jnp.asarray([1, 2, 3], jnp.int32), 8)
    out = frontier.lookup(table, jnp.asarray([INVALID], jnp.int32))
    assert int(out[0]) == -1


def test_count_valid():
    v = frontier.pad_to(jnp.asarray([5, 6], jnp.int32), 10)
    assert int(frontier.count_valid(v)) == 2
