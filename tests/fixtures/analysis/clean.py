"""Clean fixture: good key discipline + a well-formed pallas_call site.

Must produce zero error findings under every pass: keys are split
before reuse, the kernel initializes its revisited output tile with
``pl.when(p == 0)``, and every block divides its operand.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def init_params(seed):
    key = jax.random.PRNGKey(seed)
    key, kw = jax.random.split(key)
    w = jax.random.normal(kw, (4, 4))
    key, kb = jax.random.split(key)
    b = jax.random.normal(kb, (4,))
    return w, b


def _sum_kernel(x_ref, o_ref):
    p = pl.program_id(1)
    contrib = x_ref[...]

    @pl.when(p == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(p != 0)
    def _acc():
        o_ref[...] += contrib


def good_accumulate(x):
    (n,) = x.shape
    block = 8
    return pl.pallas_call(
        _sum_kernel,
        grid=(n // block, 2),
        in_specs=[pl.BlockSpec((block,), lambda i, p: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i, p: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
    )(x)


ANALYSIS_TARGETS = [
    {
        "fn": "good_accumulate",
        "args": lambda: ((jnp.zeros((16,), jnp.float32),), {}),
    },
]
