"""Known-bad fixture: host numpy inside a jit hot path -> exactly one RA002."""
import jax
import numpy as np


@jax.jit
def step(x):
    mean = np.mean(x)  # <- RA002: host numpy op under trace
    return x - mean
