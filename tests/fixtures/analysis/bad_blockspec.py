"""Known-bad fixture: BlockSpec block size does not divide the operand.

The contract checker (RA101) must flag both the input and output spec:
the operand has 128 rows but the block is 48 wide, so the final tile
reads/writes out of bounds.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_copy(x):
    (n,) = x.shape
    grid = (n // 64,)
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((48,), lambda i: (i,))],
        out_specs=pl.BlockSpec((48,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
    )(x)


ANALYSIS_TARGETS = [
    {"fn": "bad_copy", "args": lambda: ((jnp.zeros((128,), jnp.float32),), {})},
]
