"""Known-bad fixture: PRNG key consumed twice -> exactly one RA003."""
import jax


def init_params(seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (4, 4))
    b = jax.random.normal(key, (4,))  # <- RA003: key already consumed
    return w, b
