"""Known-bad fixture: revisited output tile accumulated without init.

The output index map ignores the second grid axis, so every output tile
is visited twice; the kernel accumulates with ``+=`` but never
initializes on the first visit (no ``pl.when(p == 0)`` branch) — the
contract checker must flag RA105.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acc_kernel(x_ref, o_ref):
    o_ref[...] += x_ref[...]  # <- RA105: no first-visit init


def bad_accumulate(x):
    (n,) = x.shape
    block = 8
    return pl.pallas_call(
        _acc_kernel,
        grid=(n // block, 2),
        in_specs=[pl.BlockSpec((block,), lambda i, p: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i, p: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
    )(x)


ANALYSIS_TARGETS = [
    {
        "fn": "bad_accumulate",
        "args": lambda: ((jnp.zeros((16,), jnp.float32),), {}),
    },
]
