"""``MinibatchStream`` pipeline semantics.

Prefetch depth is a *performance* knob: the items a stream yields must be
identical for prefetch = 0 / 1 / 2 under every dependency schedule, the
in-flight deque must drain fully on exhaustion, and early-stopping a
prefetching stream must yield exactly the prefix of the full run.
"""
import itertools

import numpy as np
import pytest

from repro.core.graph import INVALID
from repro.engine import CacheConfig, EngineConfig, MinibatchEngine


def _engine(small_graph, small_dataset=None, **kw):
    cfg = EngineConfig(
        local_batch=16, num_layers=2, fanout=4, sampler="ns", **kw
    )
    return MinibatchEngine.from_config(small_graph, cfg, dataset=small_dataset)


def _item_key(item):
    return (
        item.step,
        np.asarray(item.seeds).tobytes(),
        np.asarray(item.plan.input_ids).tobytes(),
        np.asarray(item.plan.seed_ids).tobytes(),
    )


SCHEDULES = [("iid", 1), ("smoothed", 4), ("nested", 4)]


@pytest.mark.parametrize("schedule,kappa", SCHEDULES)
def test_prefetch_depth_does_not_change_items(small_graph, schedule, kappa):
    """prefetch 0/1/2 yield bitwise-identical plan sequences."""
    runs = []
    for prefetch in (0, 1, 2):
        eng = _engine(
            small_graph, num_pes=2, schedule=schedule, kappa=kappa, seed=7
        )
        items = list(eng.stream(5, prefetch=prefetch))
        runs.append([_item_key(x) for x in items])
    assert runs[0] == runs[1] == runs[2]
    assert [k[0] for k in runs[0]] == list(range(5))


def test_start_step_offsets_the_schedule(small_graph):
    eng = _engine(small_graph, schedule="smoothed", kappa=4, seed=7)
    full = [_item_key(x) for x in eng.stream(6, prefetch=2)]
    tail = [_item_key(x) for x in eng.stream(3, start_step=3, prefetch=2)]
    assert full[3:] == tail


def test_exhaustion_and_empty_stream(small_graph):
    eng = _engine(small_graph)
    assert list(eng.stream(0, prefetch=2)) == []
    assert len(eng.stream(0)) == 0
    # prefetch deeper than the stream: deque must still drain completely
    items = list(eng.stream(2, prefetch=8))
    assert [x.step for x in items] == [0, 1]
    assert len(eng.stream(5, prefetch=3)) == 5


def test_early_stop_yields_exact_prefix(small_graph):
    """Breaking out of a prefetching stream == the prefix of the full run."""
    eng = _engine(small_graph, schedule="nested", kappa=4, seed=3)
    full = [_item_key(x) for x in eng.stream(6, prefetch=2)]
    prefix = [
        _item_key(x) for x in itertools.islice(eng.stream(6, prefetch=2), 3)
    ]
    assert prefix == full[:3]


def test_invalid_arguments_rejected(small_graph):
    eng = _engine(small_graph)
    with pytest.raises(ValueError):
        eng.stream(-1)
    with pytest.raises(ValueError):
        eng.stream(3, prefetch=-1)


def test_fetch_features_determinism(small_graph, small_dataset):
    """Feature prefetch through the tiered cache does not perturb the
    plan sequence, and the features themselves are replay-identical."""
    mk = lambda: _engine(
        small_graph, small_dataset, schedule="smoothed", kappa=4, seed=5,
        cache=CacheConfig(enabled=True, capacity=256),
    )
    a = list(mk().stream(4, prefetch=2, fetch_features=True))
    b = list(mk().stream(4, prefetch=0, fetch_features=True))
    assert [_item_key(x) for x in a] == [_item_key(x) for x in b]
    for ia, ib in zip(a, b):
        assert np.array_equal(np.asarray(ia.features), np.asarray(ib.features))


@pytest.mark.parametrize("schedule,kappa", SCHEDULES)
def test_seed_rows_valid(small_graph, schedule, kappa):
    eng = _engine(small_graph, num_pes=2, schedule=schedule, kappa=kappa)
    for item in eng.stream(3, prefetch=1):
        seeds = np.asarray(item.seeds)
        valid = seeds[seeds != np.int32(INVALID)]
        assert len(valid) > 0
        assert valid.min() >= 0 and valid.max() < small_graph.num_vertices
