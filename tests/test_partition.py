"""Partitioners: balance + cross-edge ratio ordering."""
import numpy as np

from repro.core.partition import (
    cross_edge_ratio,
    greedy_bfs_partition,
    hash_partition,
    make_partition,
)


def test_hash_partition_balanced(small_graph):
    p = hash_partition(small_graph.num_vertices, 4)
    counts = np.bincount(np.asarray(p.owner), minlength=4)
    assert counts.min() > 0.8 * counts.mean()


def test_hash_cross_edge_ratio_near_theory(small_graph):
    """c ~ (P-1)/P for random partitioning (§3.1)."""
    for P in (2, 4, 8):
        c = cross_edge_ratio(small_graph, hash_partition(small_graph.num_vertices, P))
        assert abs(c - (P - 1) / P) < 0.08, (P, c)


def test_bfs_partition_cuts_fewer_edges(small_graph):
    """The METIS-proxy partitioner must beat random (Table 7 premise)."""
    P = 4
    c_hash = cross_edge_ratio(small_graph, hash_partition(small_graph.num_vertices, P))
    c_bfs = cross_edge_ratio(small_graph, greedy_bfs_partition(small_graph, P))
    assert c_bfs < c_hash


def test_bfs_partition_covers_all(small_graph):
    p = greedy_bfs_partition(small_graph, 4)
    owner = np.asarray(p.owner)
    assert (owner >= 0).all() and (owner < 4).all()


def test_make_partition_dispatch(small_graph):
    for kind in ("hash", "block", "bfs"):
        p = make_partition(kind, small_graph, 4)
        assert p.num_parts == 4
