"""Partitioners: ownership invariants, balance, cross-edge ordering."""
import numpy as np
import pytest

from repro.core.partition import (
    cross_edge_ratio,
    degree_balanced_partition,
    greedy_bfs_partition,
    hash_partition,
    make_partition,
    ownership_balance,
)


def test_hash_partition_balanced(small_graph):
    p = hash_partition(small_graph.num_vertices, 4)
    counts = np.bincount(np.asarray(p.owner), minlength=4)
    assert counts.min() > 0.8 * counts.mean()


def test_hash_cross_edge_ratio_near_theory(small_graph):
    """c ~ (P-1)/P for random partitioning (§3.1)."""
    for P in (2, 4, 8):
        c = cross_edge_ratio(small_graph, hash_partition(small_graph.num_vertices, P))
        assert abs(c - (P - 1) / P) < 0.08, (P, c)


def test_bfs_partition_cuts_fewer_edges(small_graph):
    """The METIS-proxy partitioner must beat random (Table 7 premise)."""
    P = 4
    c_hash = cross_edge_ratio(small_graph, hash_partition(small_graph.num_vertices, P))
    c_bfs = cross_edge_ratio(small_graph, greedy_bfs_partition(small_graph, P))
    assert c_bfs < c_hash


def test_bfs_partition_covers_all(small_graph):
    p = greedy_bfs_partition(small_graph, 4)
    owner = np.asarray(p.owner)
    assert (owner >= 0).all() and (owner < 4).all()


def test_make_partition_dispatch(small_graph):
    for kind in ("hash", "block", "bfs", "degree"):
        p = make_partition(kind, small_graph, 4)
        assert p.num_parts == 4


@pytest.mark.parametrize("kind", ["hash", "block", "bfs", "degree"])
def test_every_vertex_owned_exactly_once(small_graph, kind):
    """1-D partitioning invariant (§3.1): the owner map is total and
    single-valued — every vertex maps to exactly one PE in [0, P)."""
    for P in (2, 4, 8):
        owner = np.asarray(make_partition(kind, small_graph, P).owner)
        assert owner.shape == (small_graph.num_vertices,)
        assert ((owner >= 0) & (owner < P)).all()
        # each vertex appears in exactly one ownership set
        sets = [np.nonzero(owner == p)[0] for p in range(P)]
        assert sum(len(s) for s in sets) == small_graph.num_vertices
        assert len(np.unique(np.concatenate(sets))) == small_graph.num_vertices


def test_degree_balanced_partition_balances_both_loads(small_graph):
    """Vertex AND edge ownership within tolerance across P (the grower's
    contract: degree-targeted growth + vertex rebalancing pass)."""
    for P in (2, 4, 8):
        part = degree_balanced_partition(small_graph, P, seed=0)
        bal = ownership_balance(small_graph, part)
        assert bal["vertices"] <= 1.10, (P, bal)
        assert bal["edges"] <= 1.35, (P, bal)


def test_degree_balanced_beats_bfs_on_edge_balance(small_graph):
    """On a power-law graph, vertex-balanced BFS skews per-PE edge load;
    the degree-balanced grower must do strictly better."""
    P = 4
    bal_deg = ownership_balance(
        small_graph, degree_balanced_partition(small_graph, P, seed=0))
    bal_bfs = ownership_balance(
        small_graph, greedy_bfs_partition(small_graph, P, seed=0))
    assert bal_deg["edges"] < bal_bfs["edges"], (bal_deg, bal_bfs)


def test_degree_balanced_locality_survives_rebalance(small_graph):
    """Rebalancing moves only cheap vertices, so the cut stays below the
    random-partition baseline c = (P-1)/P."""
    P = 4
    c = cross_edge_ratio(
        small_graph, degree_balanced_partition(small_graph, P, seed=0))
    assert c < (P - 1) / P


def test_degree_balanced_deterministic(small_graph):
    a = np.asarray(degree_balanced_partition(small_graph, 4, seed=3).owner)
    b = np.asarray(degree_balanced_partition(small_graph, 4, seed=3).owner)
    assert (a == b).all()
