"""plan_backend API: reference-vs-fused parity, seed schedule, CacheConfig.

The fused backend must be a pure lowering choice: given the same
RNGState, ``plan_backend="fused"`` and ``"reference"`` produce
bit-identical plans in every mode and schedule.  On CPU the fused ops
dispatch to their jnp oracles, so this suite pins the *algorithmic*
equivalence (fused unique-with-inverse vs unique_padded + lookup, merged
resolve pass, COO assembly); the interpret-mode kernel tests in
test_kernels.py pin the Pallas kernels against those same oracles.
"""
import warnings
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier
from repro.core.graph import INVALID
from repro.core.minibatch import layer_to_coo
from repro.engine import CacheConfig, EngineConfig, MinibatchEngine


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _engine(graph, backend, **kw):
    kw.setdefault("local_batch", 16)
    kw.setdefault("num_layers", 2)
    kw.setdefault("fanout", 4)
    kw.setdefault("sampler", "labor0")
    cfg = EngineConfig(plan_backend=backend, seed=3, **kw)
    return MinibatchEngine.from_config(graph, cfg)


CONFIGS = [
    dict(mode="independent", num_pes=1, schedule="iid"),
    dict(mode="independent", num_pes=2, schedule="smoothed", kappa=4),
    dict(mode="independent", num_pes=2, schedule="nested", kappa=4),
    dict(mode="cooperative", num_pes=2, schedule="iid"),
    dict(mode="cooperative", num_pes=2, schedule="smoothed", kappa=4),
    dict(mode="cooperative", num_pes=2, schedule="nested", kappa=4),
]


@pytest.mark.parametrize(
    "kw", CONFIGS, ids=[f"{c['mode']}-{c['schedule']}" for c in CONFIGS]
)
def test_fused_plans_bit_identical(small_graph, kw):
    ref = _engine(small_graph, "reference", **kw)
    fus = _engine(small_graph, "fused", **kw)
    for step in (0, 3, 5):
        _assert_trees_equal(ref.plan_at(step), fus.plan_at(step))


@pytest.mark.parametrize("sampler", ["ns", "full", "rw"])
def test_fused_parity_other_samplers(small_graph, sampler):
    ref = _engine(small_graph, "reference", sampler=sampler)
    fus = _engine(small_graph, "fused", sampler=sampler)
    _assert_trees_equal(ref.plan_at(1), fus.plan_at(1))


def test_plan_at_matches_build_plan(small_graph):
    """plan_at(step) == build_plan(seed_batch(step), rng_state(step))."""
    for kw in (CONFIGS[1], CONFIGS[3]):
        eng = _engine(small_graph, "reference", **kw)
        for step in (0, 4):
            direct = eng.build_plan(
                eng.seed_batch(step), rng=eng.rng_state(step)
            )
            _assert_trees_equal(eng.plan_at(step), direct)


# ---------------------------------------------------------------------------
# frontier-level overflow policy, both backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_unique_with_inverse_at_exact_capacity(backend):
    ids = jnp.asarray(np.r_[np.arange(32), np.arange(32)], jnp.int32)
    uniq, inv = frontier.unique_with_inverse(ids, 32, backend=backend)
    np.testing.assert_array_equal(np.asarray(uniq), np.arange(32))
    np.testing.assert_array_equal(
        np.asarray(inv), np.r_[np.arange(32), np.arange(32)]
    )


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_unique_with_inverse_above_capacity_keeps_smallest(backend):
    ids = jnp.asarray(np.arange(64)[::-1].copy(), jnp.int32)
    uniq, inv = frontier.unique_with_inverse(ids, 16, backend=backend)
    np.testing.assert_array_equal(np.asarray(uniq), np.arange(16))
    inv_np = np.asarray(inv)
    assert (inv_np[:48] == -1).all()        # ids 63..16 overflow
    np.testing.assert_array_equal(inv_np[48:], np.arange(16)[::-1])


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_unique_with_inverse_invalid_padding(backend):
    ids = jnp.asarray([5, INVALID, 5, 7, INVALID], jnp.int32)
    uniq, inv = frontier.unique_with_inverse(ids, 4, backend=backend)
    np.testing.assert_array_equal(np.asarray(uniq), [5, 7, INVALID, INVALID])
    np.testing.assert_array_equal(np.asarray(inv), [0, -1, 0, 1, -1])


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="plan backend"):
        frontier.unique_with_inverse(jnp.arange(4), 4, backend="gpu")
    with pytest.raises(ValueError, match="plan_backend"):
        EngineConfig(plan_backend="gpu")


# ---------------------------------------------------------------------------
# layer_to_coo
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_layer_to_coo_consistent(small_graph, backend):
    eng = _engine(small_graph, backend, num_pes=1)
    plan = eng.build_plan(eng.seed_batch(0)[0])  # 1-D plan
    layer = plan.layers[0]
    n, w = layer.nbr_idx.shape
    cap_e = n * w
    rows, cols, indptr = layer_to_coo(layer, cap_e, backend=backend)
    rows, cols, indptr = map(np.asarray, (rows, cols, indptr))
    mask = np.asarray(layer.mask)
    nbr_idx = np.asarray(layer.nbr_idx)
    total = int(mask.sum())
    assert indptr[-1] == total
    assert (rows[total:] == -1).all() and (cols[total:] == -1).all()
    # edge e sits in dst row rows[e] with src position cols[e], in
    # row-major order of the mask
    e = 0
    for i in range(n):
        assert indptr[i] == e
        for j in range(w):
            if mask[i, j]:
                assert rows[e] == i
                assert cols[e] == nbr_idx[i, j]
                e += 1
    assert e == total


# ---------------------------------------------------------------------------
# seed schedule invariants + golden pin
# ---------------------------------------------------------------------------
def test_seed_batch_golden_pin(small_graph):
    """Bit-pin the hash-permutation seed draw (regression anchor for the
    device-resident schedule that replaced the per-PE numpy loops)."""
    eng = _engine(small_graph, "reference", num_pes=2, schedule="nested",
                  kappa=4)
    got = eng.seed_batch(0)
    assert got.shape == (2, 16) and got.dtype == np.int32
    # fingerprint instead of 32 literals: stable across platforms because
    # the draw is pure integer hashing
    digest = int(np.uint64(np.abs(got.astype(np.int64) * 31).sum()))
    expect = EXPECTED_DIGESTS["nested"]
    assert digest == expect, (digest, got.tolist())
    eng_i = _engine(small_graph, "reference", num_pes=2, schedule="iid")
    got_i = eng_i.seed_batch(1)
    digest_i = int(np.uint64(np.abs(got_i.astype(np.int64) * 31).sum()))
    assert digest_i == EXPECTED_DIGESTS["iid"], (digest_i, got_i.tolist())


# weighted-sum fingerprints of seed_batch output for the configs above;
# any change to the hash-permutation draw must consciously update these
EXPECTED_DIGESTS = {"nested": 625084, "iid": 450244}


def test_nested_seed_batch_is_vectorized_and_disjoint(small_graph):
    """Sub-batches within one κ-group partition the group draw; the draw
    is a single batched permutation (no per-PE python RNG loop)."""
    eng = _engine(small_graph, "reference", num_pes=2, schedule="nested",
                  kappa=4)
    for p in range(2):
        seen = set()
        for step in range(4):
            row = eng.seed_batch(step)[p]
            row = row[row != np.int32(INVALID)]
            assert len(set(row.tolist()) & seen) == 0
            seen |= set(row.tolist())
    # next group reshuffles
    g0 = eng.seed_batch(0)
    g1 = eng.seed_batch(4)
    assert not np.array_equal(g0, g1)


def test_independent_draw_without_replacement_across_pes(small_graph):
    eng = _engine(small_graph, "reference", num_pes=4, schedule="iid",
                  local_batch=32)
    seeds = eng.seed_batch(7)
    valid = seeds[seeds != np.int32(INVALID)]
    assert len(valid) == len(set(valid.tolist()))  # global no-replacement


def test_cooperative_seed_rows_stay_owned(small_graph):
    eng = _engine(small_graph, "fused", mode="cooperative", num_pes=2)
    owner = np.asarray(eng.part.owner)
    for step in range(3):
        seeds = eng.seed_batch(step)
        for p in range(2):
            row = seeds[p][seeds[p] != np.int32(INVALID)]
            assert (owner[row] == p).all()


# ---------------------------------------------------------------------------
# CacheConfig migration
# ---------------------------------------------------------------------------
def test_legacy_cache_kwargs_warn_and_map():
    with pytest.warns(DeprecationWarning):
        cfg = EngineConfig(feature_cache=True, cache_capacity=128,
                           cache_ways=4)
    assert cfg.cache == CacheConfig(enabled=True, capacity=128, ways=4)
    # mirrored legacy attrs keep old readers working
    assert cfg.feature_cache is True
    assert cfg.cache_capacity == 128
    assert cfg.cache_ways == 4


def test_cache_config_no_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = EngineConfig(cache=CacheConfig(enabled=True, capacity=64))
    assert cfg.cache.enabled and cfg.cache.capacity == 64


def test_replace_does_not_rewarn():
    with pytest.warns(DeprecationWarning):
        cfg = EngineConfig(feature_cache=True, cache_capacity=128)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg2 = cfg.with_mode("cooperative")
        cfg3 = replace(cfg2, num_pes=2)
    assert cfg3.cache == cfg.cache


def test_conflicting_cache_specs_rejected():
    with pytest.raises(ValueError, match="disagree"):
        EngineConfig(cache=CacheConfig(enabled=True), feature_cache=False)


def test_cache_validation_still_enforced():
    with pytest.raises(ValueError):
        CacheConfig(ways=0)
    with pytest.raises(ValueError):
        CacheConfig(capacity=2, ways=8)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            EngineConfig(cache_capacity=2, cache_ways=8)
