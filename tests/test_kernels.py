"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.gather.kernel import paged_gather_pallas
from repro.kernels.gather.ref import gather_ref
from repro.kernels.seg_softmax.kernel import seg_softmax_pallas
from repro.kernels.seg_softmax.ref import seg_softmax_ref
from repro.kernels.spmm.kernel import spmm_pallas
from repro.kernels.spmm.ref import spmm_ref

R = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize(
    "S,d,n,w,block_n,block_d",
    [
        (256, 128, 128, 8, 128, 128),
        (512, 256, 256, 12, 128, 128),
        (128, 128, 128, 1, 64, 128),   # degenerate width
        (1024, 384, 384, 16, 128, 128),
    ],
)
def test_spmm_matches_ref(S, d, n, w, block_n, block_d, dtype):
    src = jnp.asarray(R.standard_normal((S, d)).astype(dtype))
    idx = jnp.asarray(R.integers(0, S, (n, w)).astype(np.int32))
    mask = jnp.asarray(R.random((n, w)) < 0.6)
    for mean in (True, False):
        out = spmm_pallas(
            src, idx, mask, mean=mean, block_n=block_n, block_d=block_d,
            interpret=True,
        )
        ref = spmm_ref(src, idx, mask, mean=mean)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_spmm_all_masked_rows_zero():
    src = jnp.ones((128, 128), jnp.float32)
    idx = jnp.zeros((128, 4), jnp.int32)
    mask = jnp.zeros((128, 4), bool)
    out = spmm_pallas(src, idx, mask, mean=True, block_n=128, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize(
    "V,d,n,page,block_n",
    [(2048, 128, 512, 512, 512), (4096, 256, 1024, 1024, 512), (1024, 128, 512, 256, 256)],
)
def test_paged_gather_matches_ref(V, d, n, page, block_n):
    tab = jnp.asarray(R.standard_normal((V, d)).astype(np.float32))
    ids = np.concatenate(
        [R.integers(0, V, n - 32), np.full(32, np.int32(2**31 - 1))]
    ).astype(np.int32)
    R.shuffle(ids)
    out = paged_gather_pallas(
        tab, jnp.asarray(ids), block_n=block_n, block_d=128, page=page,
        interpret=True,
    )
    ref = gather_ref(tab, jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([256, 512]),
    w=st.integers(min_value=1, max_value=24),
    frac=st.floats(min_value=0.1, max_value=0.9),
)
def test_seg_softmax_property(n, w, frac):
    rng = np.random.default_rng(42)
    e = jnp.asarray(rng.standard_normal((n, w)).astype(np.float32))
    mask = jnp.asarray(rng.random((n, w)) < frac)
    out = seg_softmax_pallas(e, mask, block_n=256, interpret=True)
    ref = seg_softmax_ref(e, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    out_np = np.asarray(out)
    m = np.asarray(mask)
    # rows with any valid slot sum to 1; invalid slots are exactly 0
    sums = out_np.sum(1)
    np.testing.assert_allclose(sums[m.any(1)], 1.0, atol=1e-5)
    assert (out_np[~m] == 0).all()


def test_ops_wrappers_dispatch_to_ref_on_cpu():
    """Public ops fall back to the oracle off-TPU (same math)."""
    from repro.kernels import paged_gather, seg_softmax, spmm_mean

    src = jnp.ones((64, 32), jnp.float32)
    idx = jnp.zeros((16, 4), jnp.int32)
    mask = jnp.ones((16, 4), bool)
    out = spmm_mean(src, idx, mask)
    np.testing.assert_allclose(np.asarray(out), 1.0)
    tab = jnp.arange(64, dtype=jnp.float32).reshape(16, 4)
    np.testing.assert_array_equal(
        np.asarray(paged_gather(tab, jnp.asarray([2], jnp.int32)))[0],
        np.asarray(tab[2]),
    )
    e = jnp.zeros((8, 4))
    m = jnp.ones((8, 4), bool)
    np.testing.assert_allclose(np.asarray(seg_softmax(e, m)), 0.25)


# ---------------------------------------------------------------------------
# plan-construction kernels: unique_compact / frontier_gather / expand_indptr
# ---------------------------------------------------------------------------
from repro.core import frontier
from repro.kernels.expand_indptr.kernel import expand_indptr_pallas
from repro.kernels.expand_indptr.ref import expand_indptr_ref
from repro.kernels.frontier_gather.kernel import frontier_gather_pallas
from repro.kernels.frontier_gather.ref import frontier_gather_ref
from repro.kernels.unique_compact.kernel import unique_compact_pallas
from repro.kernels.unique_compact.ref import unique_with_inverse_ref

INVALID = np.int32(2**31 - 1)


def _padded_ids(m, hi, invalid_frac, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, hi, size=m).astype(np.int32)
    ids[rng.random(m) < invalid_frac] = INVALID
    return jnp.asarray(ids)


@pytest.mark.parametrize(
    "m,cap,hi,block_m",
    [
        (512, 64, 100, 256),     # heavy duplication, overflow
        (512, 600, 100, 256),    # cap > unique count (normal regime)
        (256, 16, 8, 256),       # cap > value range: every id fits
        (1024, 128, 2**20, 256), # near-distinct ids
        (300, 64, 50, 128),      # m not a block multiple (ops pads)
    ],
)
def test_unique_compact_matches_frontier_algebra(m, cap, hi, block_m):
    """Kernel + ref both bit-match unique_padded + lookup."""
    ids = _padded_ids(m, hi, 0.2, seed=m + cap)
    uniq0 = frontier.unique_padded(ids, cap)
    inv0 = frontier.lookup(uniq0, ids)
    uniq1, inv1 = unique_with_inverse_ref(ids, cap)
    np.testing.assert_array_equal(np.asarray(uniq0), np.asarray(uniq1))
    np.testing.assert_array_equal(np.asarray(inv0), np.asarray(inv1))
    pad = (-m) % block_m
    flat = jnp.pad(ids, (0, pad), constant_values=INVALID)
    order = jnp.argsort(flat)
    inv_s, uniq2 = unique_compact_pallas(
        flat[order], cap, block_m=block_m, interpret=True
    )
    inv2 = jnp.zeros((m + pad,), jnp.int32).at[order].set(inv_s)[:m]
    np.testing.assert_array_equal(np.asarray(uniq0), np.asarray(uniq2))
    np.testing.assert_array_equal(np.asarray(inv0), np.asarray(inv2))


def test_unique_compact_all_invalid_and_empty_cap_edge():
    ids = jnp.full((256,), INVALID)
    uniq, inv = unique_with_inverse_ref(ids, 32)
    assert (np.asarray(uniq) == INVALID).all()
    assert (np.asarray(inv) == -1).all()
    inv_s, uniq_k = unique_compact_pallas(ids, 32, block_m=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(uniq_k), np.asarray(uniq))
    np.testing.assert_array_equal(np.asarray(inv_s), np.asarray(inv))


def test_frontier_gather_matches_neighbor_table(small_graph):
    g = small_graph
    n = 192
    rng = np.random.default_rng(3)
    seeds = rng.integers(0, g.num_vertices, size=n).astype(np.int32)
    seeds[rng.random(n) < 0.15] = INVALID
    seeds = jnp.asarray(seeds)
    nbr0, mask0 = g.neighbor_table(seeds)
    nbr1, mask1 = frontier_gather_ref(g.indptr, g.indices, seeds, g.max_degree)
    np.testing.assert_array_equal(np.asarray(nbr0), np.asarray(nbr1))
    np.testing.assert_array_equal(np.asarray(mask0), np.asarray(mask1))
    block_n, page = 64, 1024
    pad_n = (-n) % block_n
    pad_e = (-g.num_edges) % page
    seeds_p = jnp.pad(seeds, (0, pad_n), constant_values=INVALID)
    ind_p = jnp.pad(g.indices, (0, pad_e), constant_values=INVALID)
    nbr2 = frontier_gather_pallas(
        g.indptr, ind_p, seeds_p, max_degree=g.max_degree,
        block_n=block_n, page=page, interpret=True,
    )[:n]
    np.testing.assert_array_equal(np.asarray(nbr0), np.asarray(nbr2))
    np.testing.assert_array_equal(np.asarray(mask0), np.asarray(nbr2 != INVALID))


@pytest.mark.parametrize("R_,Ecap", [(8, 512), (256, 1024), (1, 512)])
def test_expand_indptr_matches_ref(R_, Ecap):
    rng = np.random.default_rng(R_)
    deg = rng.integers(0, 9, size=R_)
    iptr = jnp.asarray(np.concatenate([[0], np.cumsum(deg)]).astype(np.int32))
    want = expand_indptr_ref(iptr, Ecap)
    got = expand_indptr_pallas(iptr, Ecap, block_e=512, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # row ids consistent with searchsorted semantics incl. empty rows
    w = np.asarray(want)
    total = min(int(iptr[-1]), Ecap)
    assert (w[total:] == -1).all()
    for e in range(total):
        r = w[e]
        assert iptr[r] <= e < iptr[r + 1]


def test_plan_kernel_ops_dispatch_to_ref_on_cpu():
    """Public ops fall back to the oracle off-TPU (same bits)."""
    from repro.kernels import expand_indptr, frontier_gather, unique_with_inverse

    assert jax.default_backend() != "tpu"  # CI precondition
    ids = _padded_ids(400, 64, 0.1, seed=9)
    uniq, inv = unique_with_inverse(ids, 48)
    np.testing.assert_array_equal(
        np.asarray(uniq), np.asarray(frontier.unique_padded(ids, 48))
    )
    np.testing.assert_array_equal(
        np.asarray(inv), np.asarray(frontier.lookup(uniq, ids))
    )
    iptr = jnp.asarray([0, 2, 2, 5], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(expand_indptr(iptr, 8)),
        np.asarray([0, 0, 2, 2, 2, -1, -1, -1]),
    )
