"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.gather.kernel import paged_gather_pallas
from repro.kernels.gather.ref import gather_ref
from repro.kernels.seg_softmax.kernel import seg_softmax_pallas
from repro.kernels.seg_softmax.ref import seg_softmax_ref
from repro.kernels.spmm.kernel import spmm_pallas
from repro.kernels.spmm.ref import spmm_ref

R = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize(
    "S,d,n,w,block_n,block_d",
    [
        (256, 128, 128, 8, 128, 128),
        (512, 256, 256, 12, 128, 128),
        (128, 128, 128, 1, 64, 128),   # degenerate width
        (1024, 384, 384, 16, 128, 128),
    ],
)
def test_spmm_matches_ref(S, d, n, w, block_n, block_d, dtype):
    src = jnp.asarray(R.standard_normal((S, d)).astype(dtype))
    idx = jnp.asarray(R.integers(0, S, (n, w)).astype(np.int32))
    mask = jnp.asarray(R.random((n, w)) < 0.6)
    for mean in (True, False):
        out = spmm_pallas(
            src, idx, mask, mean=mean, block_n=block_n, block_d=block_d,
            interpret=True,
        )
        ref = spmm_ref(src, idx, mask, mean=mean)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_spmm_all_masked_rows_zero():
    src = jnp.ones((128, 128), jnp.float32)
    idx = jnp.zeros((128, 4), jnp.int32)
    mask = jnp.zeros((128, 4), bool)
    out = spmm_pallas(src, idx, mask, mean=True, block_n=128, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize(
    "V,d,n,page,block_n",
    [(2048, 128, 512, 512, 512), (4096, 256, 1024, 1024, 512), (1024, 128, 512, 256, 256)],
)
def test_paged_gather_matches_ref(V, d, n, page, block_n):
    tab = jnp.asarray(R.standard_normal((V, d)).astype(np.float32))
    ids = np.concatenate(
        [R.integers(0, V, n - 32), np.full(32, np.int32(2**31 - 1))]
    ).astype(np.int32)
    R.shuffle(ids)
    out = paged_gather_pallas(
        tab, jnp.asarray(ids), block_n=block_n, block_d=128, page=page,
        interpret=True,
    )
    ref = gather_ref(tab, jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([256, 512]),
    w=st.integers(min_value=1, max_value=24),
    frac=st.floats(min_value=0.1, max_value=0.9),
)
def test_seg_softmax_property(n, w, frac):
    rng = np.random.default_rng(42)
    e = jnp.asarray(rng.standard_normal((n, w)).astype(np.float32))
    mask = jnp.asarray(rng.random((n, w)) < frac)
    out = seg_softmax_pallas(e, mask, block_n=256, interpret=True)
    ref = seg_softmax_ref(e, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    out_np = np.asarray(out)
    m = np.asarray(mask)
    # rows with any valid slot sum to 1; invalid slots are exactly 0
    sums = out_np.sum(1)
    np.testing.assert_allclose(sums[m.any(1)], 1.0, atol=1e-5)
    assert (out_np[~m] == 0).all()


def test_ops_wrappers_dispatch_to_ref_on_cpu():
    """Public ops fall back to the oracle off-TPU (same math)."""
    from repro.kernels import paged_gather, seg_softmax, spmm_mean

    src = jnp.ones((64, 32), jnp.float32)
    idx = jnp.zeros((16, 4), jnp.int32)
    mask = jnp.ones((16, 4), bool)
    out = spmm_mean(src, idx, mask)
    np.testing.assert_allclose(np.asarray(out), 1.0)
    tab = jnp.arange(64, dtype=jnp.float32).reshape(16, 4)
    np.testing.assert_array_equal(
        np.asarray(paged_gather(tab, jnp.asarray([2], jnp.int32)))[0],
        np.asarray(tab[2]),
    )
    e = jnp.zeros((8, 4))
    m = jnp.ones((8, 4), bool)
    np.testing.assert_allclose(np.asarray(seg_softmax(e, m)), 0.25)
