"""repro.serve: coalescing inference — identity, ordering, accounting.

The three contracts the subsystem stands on:

1. **Bit-identity** — a seed's prediction is independent of which batch
   (bucket, policy, cache state) served it, because samplers draw
   per-vertex hash randomness and the forward is row-wise.
2. **Admission invariants** — FIFO service, dispatch never precedes
   arrival, and each policy's defining bound holds on a seeded trace.
3. **Exact accounting** — the tiered store's counters reconcile with
   ``FeatureStore.count_fetched`` on the very same id streams.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.feature_loader import FeatureStore
from repro.core.graph import INVALID
from repro.data.recsys import make_recsys, recsys_graph
from repro.engine import EngineConfig
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve.coalesce import (
    POLICIES,
    BucketedJit,
    BucketLadder,
    Coalescer,
    RetraceError,
    make_policy,
)
from repro.serve.queue import (
    Request,
    RequestQueue,
    bursty_trace,
    make_trace,
    poisson_trace,
)
from repro.serve.server import GNNServer, ServeConfig


@pytest.fixture(scope="module")
def ds():
    return make_recsys(num_users=192, num_items=96, edges_per_user=5,
                       feature_dim=16, max_degree=32, seed=0)


@pytest.fixture(scope="module")
def gnn(ds):
    return GNNConfig(model="gcn", num_layers=2, in_dim=ds.feature_dim,
                     hidden_dim=16, num_classes=ds.num_classes)


@pytest.fixture(scope="module")
def params(gnn):
    return init_gnn(jax.random.PRNGKey(0), gnn)


def _server(ds, gnn, params, **overrides):
    kw = dict(num_layers=2, fanout=4, max_batch=16, min_bucket=8,
              max_wait_ms=5.0, use_cache=False)
    kw.update(overrides)
    return GNNServer(ds.graph, ds.features, gnn, params, ServeConfig(**kw))


def _trace(ds, n=60, kind="poisson", rate=4000.0, seed=1):
    return make_trace(kind, n, rate_rps=rate, seed_pool=ds.user_ids,
                      seed=seed)


# --------------------------------------------------------------------------
# workload: recsys graph + arrival traces
# --------------------------------------------------------------------------
def test_recsys_graph_is_bipartite_and_bounded():
    g = recsys_graph(num_users=128, num_items=64, edges_per_user=4,
                     max_degree=16, seed=3)
    assert g.num_vertices == 128 + 64
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    deg = np.diff(indptr)
    assert deg.max() <= 16
    for v in range(g.num_vertices):
        nbrs = indices[indptr[v]:indptr[v + 1]]
        if v < 128:      # user -> only items
            assert (nbrs >= 128).all()
        else:            # item -> only users
            assert (nbrs < 128).all()


def test_trace_determinism_and_monotone_arrivals(ds):
    for kind in ("poisson", "bursty"):
        a = _trace(ds, 40, kind=kind, seed=7)
        b = _trace(ds, 40, kind=kind, seed=7)
        assert [(r.rid, r.seed, r.t_arrival) for r in a] == [
            (r.rid, r.seed, r.t_arrival) for r in b]
        arrivals = [r.t_arrival for r in a]
        assert arrivals == sorted(arrivals)
        assert [r.rid for r in a] == list(range(40))
        assert all(r.deadline_ms > 0 for r in a)
        assert all(int(r.seed) in set(map(int, ds.user_ids)) for r in a)
    c = _trace(ds, 40, kind="poisson", seed=8)
    assert [r.t_arrival for r in c] != [r.t_arrival for r in a]


def test_queue_take_semantics():
    reqs = [Request(i, seed=10 + i, t_arrival=i * 0.01, deadline_ms=50.0)
            for i in range(5)]
    q = RequestQueue(reqs)
    assert len(q) == 5 and q.peek_time() == 0.0
    assert q.arrival_time(2) == pytest.approx(0.02)
    first = q.take(2)
    assert [r.rid for r in first] == [0, 1]
    until = q.take_until(0.03, limit=10)
    assert [r.rid for r in until] == [2, 3]
    assert [r.rid for r in q.take(5)] == [4]
    assert not q.pending


# --------------------------------------------------------------------------
# ladder + retrace guard
# --------------------------------------------------------------------------
def test_bucket_ladder():
    lad = BucketLadder.geometric(64, min_bucket=8)
    assert lad.buckets == (8, 16, 32, 64) and lad.cap == 64
    assert lad.bucket_for(1) == 8
    assert lad.bucket_for(8) == 8
    assert lad.bucket_for(9) == 16
    assert lad.bucket_for(64) == 64
    with pytest.raises(ValueError):
        lad.bucket_for(65)
    with pytest.raises(ValueError):
        BucketLadder((16, 8))


def test_bucketed_jit_raises_on_retrace():
    bj = BucketedJit(lambda x: x * 2, lambda x: 8, name="t")
    bj(jnp.zeros((8,), jnp.float32))
    bj(jnp.ones((8,), jnp.float32))        # same shape: cached, no trace
    assert bj.compiles == {8: 1}
    with pytest.raises(RetraceError):
        bj(jnp.zeros((16,), jnp.float32))  # same bucket key, new shape


def test_coalesce_dedups_and_pads(ds, gnn):
    base = EngineConfig(mode="independent", num_pes=1, local_batch=8,
                        num_layers=2, sampler="labor0", fanout=4)
    co = Coalescer(ds.graph, base, BucketLadder.geometric(16, 8))
    u = ds.user_ids
    reqs = [Request(i, seed=int(u[i % 3]), t_arrival=0.0, deadline_ms=50.0)
            for i in range(6)]
    batch = co.coalesce(reqs, t_dispatch=0.0)
    assert batch.bucket == 8 and batch.num_unique == 3
    valid = batch.seeds[batch.seeds != INVALID]
    assert sorted(valid) == sorted(set(int(r.seed) for r in reqs))
    assert (batch.seeds[3:] == INVALID).all()
    with pytest.raises(ValueError):
        co.coalesce([], 0.0)


# --------------------------------------------------------------------------
# admission policies: defining bounds on a hand-built queue
# --------------------------------------------------------------------------
def _mkreqs(arrivals):
    return [Request(i, seed=i, t_arrival=t, deadline_ms=50.0)
            for i, t in enumerate(arrivals)]


def test_max_batch_policy_exact_batches():
    pol = make_policy("max_batch", max_batch=3, max_wait_ms=5.0)
    q = RequestQueue(_mkreqs([0.00, 0.01, 0.02, 0.03, 0.04]))
    reqs, t = pol.admit(q, now=0.0)
    assert [r.rid for r in reqs] == [0, 1, 2]
    assert t == pytest.approx(0.02)        # third arrival fills the batch
    reqs, t = pol.admit(q, now=t)
    assert [r.rid for r in reqs] == [3, 4]  # tail flush at last arrival
    assert t == pytest.approx(0.04)


def test_max_wait_policy_bounds_oldest_age():
    pol = make_policy("max_wait_ms", max_batch=16, max_wait_ms=5.0)
    q = RequestQueue(_mkreqs([0.000, 0.002, 0.004, 0.020]))
    reqs, t = pol.admit(q, now=0.0)
    # idle server: dispatch exactly when the oldest request ages out
    assert t == pytest.approx(0.005)
    assert [r.rid for r in reqs] == [0, 1, 2]
    assert all(r.t_arrival <= t for r in reqs)


def test_hybrid_policy_first_trigger_wins():
    pol = make_policy("hybrid", max_batch=2, max_wait_ms=5.0)
    q = RequestQueue(_mkreqs([0.000, 0.001, 0.050]))
    reqs, t = pol.admit(q, now=0.0)
    assert [r.rid for r in reqs] == [0, 1]   # batch filled before aging out
    assert t == pytest.approx(0.001)
    reqs, t = pol.admit(q, now=t)
    assert [r.rid for r in reqs] == [2]      # aged out before a 2nd arrival
    assert t == pytest.approx(0.055)


# --------------------------------------------------------------------------
# served-trace invariants + bit-identity
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def indep_report(ds, gnn, params):
    return _server(ds, gnn, params).serve_independent(_trace(ds))


def test_independent_baseline_sanity(indep_report):
    assert len(indep_report.served) == 60
    assert all(b.num_requests == 1 for b in indep_report.batches)
    assert 0.0 <= indep_report.slo_attainment <= 1.0


@pytest.mark.parametrize("policy", POLICIES)
def test_coalesced_bit_identical_to_per_request(ds, gnn, params,
                                                indep_report, policy):
    rep = _server(ds, gnn, params, policy=policy).serve_trace(_trace(ds))
    assert len(rep.served) == len(indep_report.served)
    ref = {s.request.rid: s.pred for s in indep_report.served}
    for s in rep.served:
        assert np.array_equal(s.pred, ref[s.request.rid]), (
            policy, s.request.rid)


def test_bit_identity_survives_warm_cache(ds, gnn, params, indep_report):
    rep = _server(ds, gnn, params, policy="hybrid",
                  use_cache=True).serve_trace(_trace(ds))
    ref = {s.request.rid: s.pred for s in indep_report.served}
    assert all(np.array_equal(s.pred, ref[s.request.rid])
               for s in rep.served)


@pytest.mark.parametrize("policy", POLICIES)
def test_ordering_and_deadline_invariants(ds, gnn, params, policy):
    trace = _trace(ds, kind="bursty", seed=5)
    rep = _server(ds, gnn, params, policy=policy).serve_trace(trace)
    by_rid = sorted(rep.served, key=lambda s: s.request.rid)
    # dispatch never precedes arrival; completion strictly follows dispatch
    for s in by_rid:
        assert s.t_dispatch >= s.request.t_arrival - 1e-12
        assert s.t_complete > s.t_dispatch
        assert s.met_deadline == (s.latency_ms <= s.request.deadline_ms)
    # FIFO: arrival order never overtakes batch order
    idx = [s.batch_index for s in by_rid]
    assert idx == sorted(idx)
    disp = [b.t_dispatch for b in rep.batches]
    assert disp == sorted(disp)
    if policy == "max_batch":
        assert all(b.num_requests == 16 for b in rep.batches[:-1])
    assert all(b.num_requests <= 16 for b in rep.batches)
    assert all(b.num_unique <= b.num_requests for b in rep.batches)


def test_compiles_once_per_bucket_across_traces(ds, gnn, params):
    srv = _server(ds, gnn, params, policy="hybrid")
    rep1 = srv.serve_trace(_trace(ds, seed=1))
    rep2 = srv.serve_trace(_trace(ds, seed=2))  # warm: must not retrace
    for rep in (rep1, rep2):
        assert all(n == 1 for n in rep.compiles["serve.plan"].values())
        assert all(n == 1 for n in rep.compiles["serve.forward"].values())
    assert set(rep2.compiles["serve.forward"]) <= {8, 16}


def test_modeled_clock_is_deterministic(ds, gnn, params):
    t = _trace(ds, seed=9)
    a = _server(ds, gnn, params, policy="hybrid").serve_trace(t)
    b = _server(ds, gnn, params, policy="hybrid").serve_trace(t)
    assert a.summary() == b.summary()
    assert np.array_equal(a.latencies_ms(), b.latencies_ms())


# --------------------------------------------------------------------------
# fetched-rows accounting: tiered counters vs the oracle count_fetched
# --------------------------------------------------------------------------
def test_cache_accounting_reconciles_with_count_fetched(ds, gnn, params):
    trace = _trace(ds, seed=4)
    srv = _server(ds, gnn, params, policy="hybrid", use_cache=True)
    rep = srv.serve_trace(trace)

    # replay each batch's plan eagerly: the tiered `requested` counter
    # must equal the oracle's unique-valid count summed over batches
    oracle = FeatureStore(ds.features)
    by_batch = {}
    for s in rep.served:
        by_batch.setdefault(s.batch_index, []).append(s.request)
    expect_requested = 0
    all_ids = []
    for i in sorted(by_batch):
        batch = srv.coalescer.coalesce(by_batch[i], t_dispatch=0.0)
        plan = srv.coalescer.build_plan(batch)
        ids = np.asarray(plan.input_ids)
        expect_requested += oracle.count_fetched(ids)
        all_ids.append(ids.ravel())
    assert rep.requested_rows == expect_requested
    assert rep.cache_hits + srv.tiered.misses == rep.requested_rows

    # a cache big enough for every row fetches each distinct row once
    cap = ds.graph.num_vertices + (-ds.graph.num_vertices % 8)
    big = _server(ds, gnn, params, policy="hybrid", use_cache=True,
                  cache_capacity=cap)
    rep_big = big.serve_trace(trace)
    ids = np.concatenate(all_ids)
    global_unique = len(np.unique(ids[ids != INVALID]))
    assert rep_big.fetched_rows == global_unique


def test_per_batch_fetch_counts_without_cache(ds, gnn, params):
    rep = _server(ds, gnn, params, policy="max_batch").serve_trace(
        _trace(ds, seed=6))
    assert rep.fetched_rows == sum(b.fetched_rows for b in rep.batches)
    assert rep.requested_rows == rep.fetched_rows
    for b in rep.batches:
        assert b.fetched_rows >= b.num_unique   # seeds are always inputs
        assert b.edges > 0
