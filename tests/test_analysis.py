"""repro.analysis: lint rules, kernel contracts, trace hygiene, CLI."""
import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import run_analysis
from repro.analysis.cli import main
from repro.analysis.findings import (
    Finding,
    Report,
    Severity,
    suppressed_rules,
)
from repro.core.graph import Graph, GraphValidationError
from repro.kernels.errors import KernelContractError

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def fixture(name):
    return os.path.join(FIXTURES, name)


def errors(report):
    return [f for f in report.findings if f.severity >= Severity.ERROR]


# --- known-bad fixtures: each must produce exactly the expected finding ----

def test_bad_key_reuse_fixture():
    rep = run_analysis([fixture("bad_key_reuse.py")], passes=["lint"])
    errs = errors(rep)
    assert [f.rule for f in errs] == ["RA003"]
    assert errs[0].line == 8
    assert errs[0].file.endswith("bad_key_reuse.py")
    assert "key" in errs[0].message


def test_bad_numpy_hot_fixture():
    rep = run_analysis([fixture("bad_numpy_hot.py")], passes=["lint"])
    errs = errors(rep)
    assert [f.rule for f in errs] == ["RA002"]
    assert errs[0].line == 8
    assert "numpy.mean" in errs[0].message


def test_bad_blockspec_fixture():
    rep = run_analysis([fixture("bad_blockspec.py")], passes=["contracts"])
    errs = errors(rep)
    # both the input and the output spec use the bad 48-wide block
    assert [f.rule for f in errs] == ["RA101", "RA101"]
    assert all(f.line == 19 for f in errs), [f.line for f in errs]
    assert errs[0].extra["block"] == 48 and errs[0].extra["size"] == 128


def test_bad_missing_init_fixture():
    rep = run_analysis([fixture("bad_missing_init.py")], passes=["contracts"])
    errs = errors(rep)
    assert [f.rule for f in errs] == ["RA105"]
    assert "pl.when" in errs[0].message


def test_clean_fixture_all_rules():
    rep = run_analysis(
        [fixture("clean.py")], passes=["lint", "contracts"]
    )
    assert errors(rep) == []
    # the well-formed pallas site is positively verified
    assert any(f.rule == "RA100" for f in rep.findings)


def test_clean_repo_src():
    """The shipped tree must carry zero error-severity findings."""
    rep = run_analysis([SRC], passes=["lint", "contracts"])
    assert errors(rep) == [], "\n".join(f.render() for f in errors(rep))
    assert rep.files_scanned > 50
    # the contract checker positively verified all three Pallas kernels
    verified = {
        f.extra.get("kernel") for f in rep.findings if f.rule == "RA100"
    }
    assert {"gather", "spmm", "seg_softmax"} <= verified


def test_trace_pass_clean_on_repo():
    from repro.analysis.trace import run_trace

    findings = run_trace()
    errs = [f for f in findings if f.severity >= Severity.ERROR]
    assert errs == [], "\n".join(f.render() for f in errs)
    # every entry reported a single shared trace
    names = {f.message.split("`")[1] for f in findings if f.rule == "RA200"}
    assert "engine.build_plan[smoothed]" in names


def test_trace_pass_detects_recompilation():
    from repro.analysis.trace import TraceEntry, run_trace

    def build():
        def fn(x):
            return x + 1

        # python floats are weak-typed: f32 vs f64-weak retraces
        a = jnp.float32(1.0)
        return fn, (), [
            lambda: ((a,), {}),
            lambda: ((jnp.asarray(2, jnp.int32),), {}),  # dtype change
        ]

    findings = run_trace([TraceEntry("synthetic.retrace", "<test>", build)])
    assert [f.rule for f in findings] == ["RA201"]
    assert findings[0].extra["traces"] == 2


# --- lint framework mechanics ----------------------------------------------

def test_inline_suppression(tmp_path):
    p = tmp_path / "suppressed.py"
    p.write_text(
        "import jax\n\n"
        "def f(seed):\n"
        "    key = jax.random.PRNGKey(seed)\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))  # ra: ignore[RA003]\n"
        "    return a, b\n"
    )
    rep = run_analysis([str(p)], passes=["lint"])
    assert errors(rep) == []
    # a non-matching id does NOT suppress
    p.write_text(p.read_text().replace("RA003", "RA001"))
    rep = run_analysis([str(p)], passes=["lint"])
    assert [f.rule for f in errors(rep)] == ["RA003"]


def test_suppression_parsing():
    assert suppressed_rules("x = 1") is None
    assert suppressed_rules("x = 1  # ra: ignore") == frozenset()
    assert suppressed_rules("x  # repro-analysis: ignore[RA001, RA003]") == {
        "RA001", "RA003",
    }


def test_hot_path_requires_jit_scope(tmp_path):
    # the same numpy call OUTSIDE a jit scope is fine
    p = tmp_path / "coldpath.py"
    p.write_text(
        "import numpy as np\n\n"
        "def host_prep(x):\n"
        "    return np.asarray(x).mean()\n"
    )
    rep = run_analysis([str(p)], passes=["lint"])
    assert rep.findings == []


def test_stream_class_is_hot(tmp_path):
    p = tmp_path / "stream_like.py"
    p.write_text(
        "class MinibatchStream:\n"
        "    def _make(self, plan):\n"
        "        return plan.ids.item()\n"
    )
    rep = run_analysis([str(p)], passes=["lint"])
    assert [f.rule for f in errors(rep)] == ["RA001"]


# --- report / CLI -----------------------------------------------------------

def test_report_json_round_trip():
    rep = Report(
        findings=[
            Finding("RA001", Severity.ERROR, "m", "f.py", 3),
            Finding("RA100", Severity.INFO, "ok", "g.py", 1),
        ],
        passes_run=["lint"],
        files_scanned=2,
    )
    d = json.loads(rep.render_json())
    assert d["rule_counts"] == {"RA001": 1, "RA100": 1}
    assert d["counts"]["error"] == 1
    assert d["findings"][0]["file"] == "f.py"
    assert rep.exit_code() == 1
    assert rep.exit_code(Severity.INFO) == 1
    assert Report().exit_code() == 0


def test_cli_json_and_exit_codes(tmp_path, capsys):
    out_file = tmp_path / "report.json"
    code = main([
        fixture("bad_blockspec.py"), "--format", "json",
        "--output", str(out_file),
    ])
    assert code == 1
    payload = json.loads(out_file.read_text())
    assert payload["rule_counts"] == {"RA101": 2}
    capsys.readouterr()
    assert main([fixture("clean.py")]) == 0
    capsys.readouterr()


def test_cli_module_entry_point():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         fixture("bad_missing_init.py"), "--format", "json"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC},
        cwd=REPO,
    )
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["rule_counts"] == {"RA105": 1}


def test_fail_on_warning_gate(tmp_path, capsys):
    # RA105 warning variant: revisited tile, no accumulation, no init
    p = tmp_path / "warn_kernel.py"
    p.write_text(
        "import jax\n"
        "import jax.numpy as jnp\n"
        "from jax.experimental import pallas as pl\n\n\n"
        "def _k(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n\n\n"
        "def overwrite(x):\n"
        "    (n,) = x.shape\n"
        "    return pl.pallas_call(\n"
        "        _k, grid=(n // 8, 2),\n"
        "        in_specs=[pl.BlockSpec((8,), lambda i, p: (i,))],\n"
        "        out_specs=pl.BlockSpec((8,), lambda i, p: (i,)),\n"
        "        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),\n"
        "    )(x)\n\n\n"
        "ANALYSIS_TARGETS = [\n"
        "    {'fn': 'overwrite',\n"
        "     'args': lambda: ((jnp.zeros((16,), jnp.float32),), {})},\n"
        "]\n"
    )
    assert main([str(p), "--passes", "contracts"]) == 0
    capsys.readouterr()
    assert main(
        [str(p), "--passes", "contracts", "--fail-on", "warning"]
    ) == 1
    capsys.readouterr()


# --- kernel contract errors (typed preconditions) ---------------------------

def test_kernel_contract_errors_carry_values():
    from repro.kernels.gather.kernel import paged_gather_pallas
    from repro.kernels.seg_softmax.kernel import seg_softmax_pallas
    from repro.kernels.spmm.kernel import spmm_pallas

    with pytest.raises(KernelContractError) as ei:
        paged_gather_pallas(
            jnp.zeros((100, 128)), jnp.zeros((64,), jnp.int32),
            block_n=64, block_d=128, page=64, interpret=True,
        )
    assert ei.value.kernel == "paged_gather_pallas"
    assert ei.value.values == {"V": 100, "page": 64}
    assert "V % page" in str(ei.value)

    with pytest.raises(KernelContractError) as ei:
        spmm_pallas(
            jnp.zeros((64, 100)), jnp.zeros((8, 4), jnp.int32),
            jnp.ones((8, 4), bool), block_n=8, block_d=128, interpret=True,
        )
    assert ei.value.values == {"d": 100, "block_d": 128}

    with pytest.raises(KernelContractError):
        seg_softmax_pallas(
            jnp.zeros((100, 4)), jnp.ones((100, 4), bool),
            block_n=64, interpret=True,
        )


def test_kernels_still_work_after_contract_change():
    from repro.kernels.seg_softmax.kernel import seg_softmax_pallas
    from repro.kernels.seg_softmax.ref import seg_softmax_ref

    e = jnp.asarray(np.random.default_rng(0).standard_normal((16, 4)),
                    jnp.float32)
    mask = jnp.ones((16, 4), bool)
    out = seg_softmax_pallas(e, mask, block_n=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(seg_softmax_ref(e, mask)), atol=1e-5
    )


# --- Graph.validate ---------------------------------------------------------

def _ring_graph():
    return Graph.from_edges(
        np.array([0, 1, 2, 3]), np.array([1, 2, 3, 0]), num_vertices=4
    )


def test_graph_validate_accepts_well_formed():
    g = _ring_graph()
    assert g.validate() is g  # chains


def test_graph_validate_rejects_corruption():
    g = _ring_graph()

    bad_indptr = dataclasses.replace(
        g, indptr=jnp.asarray([0, 3, 1, 2, 4], jnp.int32)
    )
    with pytest.raises(GraphValidationError, match="monotone"):
        bad_indptr.validate()

    bad_indices = dataclasses.replace(
        g, indices=jnp.asarray([0, 1, 9, 2], jnp.int32)
    )
    with pytest.raises(GraphValidationError, match="outside"):
        bad_indices.validate()

    bad_dtype = dataclasses.replace(
        g, indices=g.indices.astype(jnp.float32)
    )
    with pytest.raises(GraphValidationError, match="dtype"):
        bad_dtype.validate()

    bad_len = dataclasses.replace(
        g, indptr=jnp.asarray([0, 1, 2, 4], jnp.int32)
    )
    with pytest.raises(GraphValidationError, match="indptr shape"):
        bad_len.validate()


def test_engine_rejects_malformed_graph():
    from repro.engine import EngineConfig, MinibatchEngine

    g = _ring_graph()
    bad = dataclasses.replace(
        g, indices=jnp.asarray([0, 1, 9, 2], jnp.int32)
    )
    with pytest.raises(GraphValidationError):
        MinibatchEngine.from_config(
            bad, EngineConfig(local_batch=4, num_layers=1, fanout=2)
        )
