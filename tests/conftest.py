"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on ONE device;
only the dry-run materializes the 512-device host platform."""
import jax
import numpy as np
import pytest

from repro.data.synthetic import SyntheticGraphDataset, rmat_graph


@pytest.fixture(scope="session")
def small_graph():
    return rmat_graph(scale=10, edge_factor=8, max_degree=32, seed=0)


@pytest.fixture(scope="session")
def small_dataset(small_graph):
    return SyntheticGraphDataset(small_graph, feature_dim=32, num_classes=8, seed=0)


@pytest.fixture(scope="session")
def rel_graph():
    return rmat_graph(scale=9, edge_factor=6, max_degree=24, num_edge_types=4, seed=1)
