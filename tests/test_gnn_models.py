"""GNN models + training loop: shapes, learning signal, coop==indep code."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import GNNConfig, gnn_apply, init_gnn
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.loop import TrainConfig, train_gnn
from repro.train.optim import adam_init, adam_update


@pytest.mark.parametrize("model", ["gcn", "sage", "gat", "rgcn"])
def test_models_train_one_step(small_dataset, model, rel_graph):
    from repro.data.synthetic import SyntheticGraphDataset

    if model == "rgcn":
        ds = SyntheticGraphDataset(rel_graph, feature_dim=16, num_classes=4, seed=1)
        cfg = GNNConfig(model=model, num_layers=2, in_dim=16, hidden_dim=32,
                        num_classes=4, num_relations=4)
    else:
        ds = small_dataset
        cfg = GNNConfig(model=model, num_layers=2, in_dim=32, hidden_dim=32,
                        num_classes=8)
    tc = TrainConfig(mode="independent", num_pes=2, local_batch=16,
                     num_steps=2, fanout=4, eval_every=0)
    r = train_gnn(ds, cfg, tc)
    assert len(r.losses) == 2
    assert all(np.isfinite(r.losses))


def test_cooperative_loss_decreases(small_dataset):
    cfg = GNNConfig(model="gcn", num_layers=2, in_dim=32, hidden_dim=64, num_classes=8)
    tc = TrainConfig(mode="cooperative", num_pes=2, local_batch=32,
                     num_steps=25, fanout=5, eval_every=0)
    r = train_gnn(small_dataset, cfg, tc)
    assert np.mean(r.losses[-5:]) < np.mean(r.losses[:5])


def test_dependent_kappa_trains(small_dataset):
    cfg = GNNConfig(model="gcn", num_layers=2, in_dim=32, hidden_dim=32, num_classes=8)
    tc = TrainConfig(mode="cooperative", num_pes=2, local_batch=16,
                     num_steps=6, fanout=4, kappa=4, eval_every=0)
    r = train_gnn(small_dataset, cfg, tc)
    assert all(np.isfinite(r.losses))


def test_adam_reduces_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, opt = adam_update(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["x"]).max()) < 0.5


def test_checkpoint_roundtrip(tmp_path):
    cfg = GNNConfig(model="gcn", num_layers=2, in_dim=8, hidden_dim=8, num_classes=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, extra={"step": 3})
    restored = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
