"""Sampler semantics: fanout bounds, LABOR sharing, determinism."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier
from repro.core.graph import INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler

RNG = DependentRNG(base_seed=3, kappa=1, step=0)


def _seeds(n=64, cap=80):
    return frontier.pad_to(jnp.arange(n, dtype=jnp.int32), cap)


@pytest.mark.parametrize("name", ["ns", "labor0", "labor*", "rw", "full"])
def test_sampled_edges_are_real_edges(small_graph, name):
    s = make_sampler(name, fanout=5)
    ls = s.sample_layer(small_graph, _seeds(), RNG, 0)
    indptr = np.asarray(small_graph.indptr)
    indices = np.asarray(small_graph.indices)
    nbr, mask, seeds = np.asarray(ls.nbr), np.asarray(ls.mask), np.asarray(ls.seeds)
    for i in range(len(seeds)):
        if seeds[i] == INVALID:
            assert not mask[i].any()
            continue
        true_nbrs = set(indices[indptr[seeds[i]] : indptr[seeds[i] + 1]].tolist())
        for j in range(nbr.shape[1]):
            if mask[i, j] and name != "rw":  # rw reaches multi-hop vertices
                assert nbr[i, j] in true_nbrs, (name, seeds[i], nbr[i, j])


def test_ns_respects_fanout(small_graph):
    s = make_sampler("ns", fanout=5)
    ls = s.sample_layer(small_graph, _seeds(), RNG, 0)
    assert ls.nbr.shape[1] == 5
    deg = np.asarray(small_graph.degrees)[: 64]
    got = np.asarray(ls.mask[:64]).sum(1)
    np.testing.assert_array_equal(got, np.minimum(deg, 5))


def test_labor0_expected_edges_close_to_fanout(small_graph):
    k = 5
    s = make_sampler("labor0", fanout=k)
    counts = []
    for t in range(10):
        rng = DependentRNG(base_seed=100 + t, kappa=1, step=0)
        ls = s.sample_layer(small_graph, _seeds(), rng, 0)
        counts.append(np.asarray(ls.mask).sum(1))
    mean_edges = np.stack(counts).mean(0)
    deg = np.asarray(small_graph.degrees)[:64]
    expect = np.minimum(deg, k)
    # E[edges per seed] == min(deg, k) for LABOR-0
    assert np.abs(mean_edges[:64] - expect).mean() < 1.0


def test_labor_shares_variates_across_seeds(small_graph):
    """The SAME source vertex is accepted/rejected consistently batch-wide."""
    s = make_sampler("labor0", fanout=3)
    ls = s.sample_layer(small_graph, _seeds(128, 128), RNG, 0)
    nbr, mask = np.asarray(ls.nbr), np.asarray(ls.mask)
    deg = np.asarray(small_graph.degrees)
    # a source with deg_s equal for two seeds is accepted for both or neither
    seen = {}
    for i in range(128):
        for j in range(nbr.shape[1]):
            if nbr[i, j] == INVALID:
                continue
            key = (int(nbr[i, j]), int(deg[i]))
            if key in seen:
                assert seen[key] == bool(mask[i, j])
            seen[key] = bool(mask[i, j])


def test_labor_star_samples_fewer_unique(small_graph):
    """LABOR-* <= LABOR-0 <= NS in unique sampled vertices (Fig. 3 order)."""
    uniq = {}
    for name in ("ns", "labor0", "labor*"):
        s = make_sampler(name, fanout=5)
        tot = 0
        for t in range(8):
            rng = DependentRNG(base_seed=50 + t, kappa=1, step=0)
            ls = s.sample_layer(small_graph, _seeds(256, 256), rng, 0)
            u = frontier.unique_padded(ls.nbr, 4096)
            tot += int(frontier.count_valid(u))
        uniq[name] = tot / 8
    assert uniq["labor0"] <= uniq["ns"] * 1.02
    assert uniq["labor*"] <= uniq["labor0"] * 1.05


def test_sampler_determinism(small_graph):
    s = make_sampler("ns", fanout=4)
    a = s.sample_layer(small_graph, _seeds(), RNG, 0)
    b = s.sample_layer(small_graph, _seeds(), RNG, 0)
    np.testing.assert_array_equal(np.asarray(a.nbr), np.asarray(b.nbr))


def test_rw_returns_visited_vertices(small_graph):
    s = make_sampler("rw", fanout=5, walk_length=3, num_walks=8)
    ls = s.sample_layer(small_graph, _seeds(), RNG, 0)
    assert int(ls.num_edges) > 0
    # no seed lists itself as its own neighbor
    nbr, seeds = np.asarray(ls.nbr), np.asarray(ls.seeds)
    for i in range(64):
        assert seeds[i] not in nbr[i][np.asarray(ls.mask[i])]
