"""Graph container + synthetic generator invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph, INVALID
from repro.data.synthetic import rmat_graph


def test_csr_roundtrip():
    src = np.array([1, 2, 3, 0, 2])
    dst = np.array([0, 0, 1, 2, 3])
    g = Graph.from_edges(src, dst, num_vertices=4)
    assert g.num_edges == 5
    assert g.num_vertices == 4
    nbr, mask = g.neighbor_table(jnp.arange(4, dtype=jnp.int32))
    # N(0) = {1, 2}
    n0 = sorted(np.asarray(nbr[0])[np.asarray(mask[0])].tolist())
    assert n0 == [1, 2]
    n3 = np.asarray(nbr[3])[np.asarray(mask[3])].tolist()
    assert n3 == [2]


def test_degree_cap():
    src = np.repeat(np.arange(50), 1)
    dst = np.zeros(50, dtype=np.int64)
    g = Graph.from_edges(src, dst, num_vertices=50, max_degree=8)
    assert int(g.degrees[0]) == 8
    assert g.max_degree == 8


def test_invalid_seed_rows_masked(small_graph):
    seeds = jnp.asarray([0, 1, INVALID], jnp.int32)
    nbr, mask = small_graph.neighbor_table(seeds)
    assert not bool(mask[2].any())
    assert bool((nbr[2] == INVALID).all())


def test_rmat_shape_stats():
    g = rmat_graph(scale=10, edge_factor=8, max_degree=64, seed=0)
    assert g.num_vertices == 1024
    deg = np.asarray(g.degrees)
    assert deg.max() <= 64
    # power-law-ish: a heavy tail exists
    assert deg.max() >= 4 * max(1, int(np.median(deg)))


def test_edge_types_aligned(rel_graph):
    seeds = jnp.arange(16, dtype=jnp.int32)
    et = rel_graph.neighbor_edge_types(seeds)
    _, mask = rel_graph.neighbor_table(seeds)
    assert et.shape == mask.shape
    assert int(et.max()) < rel_graph.num_edge_types
