"""Unified minibatching engine: one facade over both modes of the paper.

    cfg = EngineConfig(mode="cooperative", num_pes=4, local_batch=64,
                       num_layers=3, sampler="labor0", fanout=10,
                       schedule="smoothed", kappa=16)
    engine = MinibatchEngine.from_config(graph, cfg, dataset=ds)
    for item in engine.stream(num_steps=100):
        H = item.plan.gather_inputs(store)
        logits = engine.apply_model(params, gnn_cfg, item.plan, H)

Swap ``mode="independent"`` and nothing else changes — the paper's
controlled comparison (§4.3) in one flag.  The low-level builders in
``repro.core`` remain the stable kernel layer underneath.
"""
from repro.engine.config import CacheConfig, CapacityPolicy, EngineConfig
from repro.engine.engine import MinibatchEngine
from repro.engine.plan import Plan
from repro.engine.shard import ShardRunner
from repro.engine.stream import MinibatchStream, StreamItem

__all__ = [
    "CacheConfig",
    "CapacityPolicy",
    "EngineConfig",
    "MinibatchEngine",
    "MinibatchStream",
    "Plan",
    "ShardRunner",
    "StreamItem",
]
