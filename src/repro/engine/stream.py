"""Streaming plan iterator with host-side double-buffered prefetch.

Plan construction is jit-dispatched and executes asynchronously; the
stream exploits that by *dispatching* the builds for the next
``prefetch`` steps before the consumer touches the current plan, so
host-side seed generation and device-side sampling overlap with
consumption.  For dependent schedules (smoothed-κ / nested-κ) this is
what hides the per-step plan build behind the previous step's compute —
the pipelining the paper assumes when it prices sampling at
``|S^l|/β`` overlap-able bandwidth (Table 1).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterator, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    import jax

    from repro.core.rng import DependentRNG
    from repro.engine.engine import MinibatchEngine
    from repro.engine.plan import Plan


@dataclass(frozen=True)
class StreamItem:
    """One pipeline step: the plan plus the RNG that sampled it."""

    step: int
    plan: "Plan"
    rng: "DependentRNG"
    seeds: np.ndarray  # (P, b) host-side seed rows
    features: "Optional[jax.Array]" = None  # input-layer H when prefetched


class MinibatchStream:
    """Iterator over :class:`StreamItem`; ``prefetch`` builds in flight.

    ``prefetch=2`` is classic double buffering: while the consumer uses
    plan *i*, plan *i+1* is already dispatched.  ``prefetch=0`` degrades
    to fully synchronous iteration (useful for debugging).

    ``fetch_features=True`` additionally loads the plan's input-layer
    embeddings at dispatch time (through the engine's tiered store when
    configured), so cache fills — host-tier fetches for cache misses —
    overlap with the consumer's compute on the previous step instead of
    stalling it.
    """

    def __init__(
        self,
        engine: "MinibatchEngine",
        num_steps: int,
        start_step: int = 0,
        prefetch: int = 2,
        fetch_features: bool = False,
    ):
        if num_steps < 0 or prefetch < 0:
            raise ValueError("num_steps and prefetch must be >= 0")
        self.engine = engine
        self.num_steps = num_steps
        self.start_step = start_step
        self.prefetch = prefetch
        self.fetch_features = fetch_features

    def _make(self, step: int) -> StreamItem:
        eng = self.engine
        # one fused dispatch: seed draw + schedule RNG + sampling stay on
        # device (plan_at); the host-side seeds/rng mirrors exposed on the
        # StreamItem recompute the same bits and are cheap by comparison
        plan = eng.plan_at(step)
        seeds = eng.seed_batch(step)
        rng = eng.rng_at(step)
        feats = eng.gather_features(plan) if self.fetch_features else None
        return StreamItem(
            step=step, plan=plan, rng=rng, seeds=seeds, features=feats
        )

    def __len__(self) -> int:
        return self.num_steps

    def __iter__(self) -> Iterator[StreamItem]:
        buf: deque[StreamItem] = deque()
        depth = max(1, self.prefetch)
        for step in range(self.start_step, self.start_step + self.num_steps):
            buf.append(self._make(step))
            if len(buf) >= depth:
                yield buf.popleft()
        while buf:
            yield buf.popleft()
