"""Engine configuration: everything that fixes a minibatching pipeline.

One :class:`EngineConfig` pins the paper's whole experimental axis system
(§3.1–§3.2): minibatching mode (independent vs cooperative at identical
global batch size), sampler, layer/fanout budget, capacity policy,
dependency schedule (iid / smoothed-κ / nested-κ), partition strategy,
executor backend, plan-construction backend, and the tiered feature
cache.  :class:`repro.engine.MinibatchEngine.from_config` derives all the
kernel-layer objects (capacity plans, partitions, seed generators,
executors) from it so consumers never hand-wire them.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Optional

MODES = ("independent", "cooperative")
SCHEDULES = ("iid", "smoothed", "nested")
EXECUTORS = ("sim", "shard")
PLAN_BACKENDS = ("reference", "fused")

_UNSET = object()  # sentinel distinguishing "not passed" for legacy kwargs


@dataclass(frozen=True)
class CapacityPolicy:
    """Safety factors feeding the geometric capacity bounds (Thm 3.2).

    Defaults match ``CapacityPlan.geometric`` / ``CoopCapacityPlan.geometric``
    so engine-built plans are bit-identical to hand-built ones.
    """

    safety: float = 1.25          # independent frontier growth slack
    coop_safety: float = 1.5      # cooperative owned/request frontier slack
    bucket_safety: float = 2.5    # per-peer A2A bucket slack
    round_to: int = 8


@dataclass(frozen=True)
class CacheConfig:
    """Tiered feature store (repro.store): device CLOCK cache per PE in
    front of the host feature tier.  ``capacity=None`` defaults to
    ``V // 4`` rows at engine construction."""

    enabled: bool = False
    capacity: Optional[int] = None  # rows per PE
    ways: int = 8

    def __post_init__(self):
        if self.ways < 1:
            raise ValueError("cache_ways must be >= 1")
        if self.capacity is not None and self.capacity < self.ways:
            raise ValueError("cache_capacity must be >= cache_ways")


@dataclass(frozen=True)
class EngineConfig:
    """Declarative spec for a :class:`repro.engine.MinibatchEngine`."""

    mode: str = "independent"            # independent | cooperative
    num_pes: int = 1                     # P; global batch = local_batch * P
    local_batch: int = 64                # b
    num_layers: int = 2                  # L
    sampler: str = "labor0"              # ns | labor0 | labor* | rw | full
    fanout: int = 10
    schedule: str = "iid"                # iid | smoothed | nested
    kappa: Optional[int] = 1             # dependency window (None = infinite)
    partition: str = "hash"              # hash | block | bfs (cooperative only)
    executor: str = "sim"                # sim | shard (cooperative only)
    axis_name: str = "data"              # mesh axis for the shard executor
    seed: int = 0
    partition_seed: Optional[int] = None  # defaults to ``seed``
    capacity: CapacityPolicy = field(default_factory=CapacityPolicy)
    # how plan construction lowers: "reference" keeps the jnp
    # sort/searchsorted frontier algebra; "fused" routes the hot loop
    # through the Pallas kernels (unique_compact / frontier_gather /
    # expand_indptr).  Bit-identical outputs either way.
    plan_backend: str = "reference"
    cache: Optional[CacheConfig] = None
    # deprecated flat aliases for ``cache`` — kept so old configs keep
    # constructing; emit DeprecationWarning when used
    feature_cache: object = _UNSET       # -> cache.enabled
    cache_capacity: object = _UNSET      # -> cache.capacity
    cache_ways: object = _UNSET          # -> cache.ways

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, got {self.schedule!r}"
            )
        if self.executor not in EXECUTORS:
            raise ValueError(
                f"executor must be one of {EXECUTORS}, got {self.executor!r}"
            )
        if self.plan_backend not in PLAN_BACKENDS:
            raise ValueError(
                f"plan_backend must be one of {PLAN_BACKENDS}, "
                f"got {self.plan_backend!r}"
            )
        if self.num_pes < 1 or self.local_batch < 1 or self.num_layers < 1:
            raise ValueError("num_pes, local_batch, num_layers must be >= 1")
        if self.schedule == "nested" and not self.kappa:
            raise ValueError("nested schedule requires a finite kappa >= 1")
        self._resolve_cache()

    def _resolve_cache(self):
        legacy = {
            "enabled": self.feature_cache,
            "capacity": self.cache_capacity,
            "ways": self.cache_ways,
        }
        given = {k: v for k, v in legacy.items() if v is not _UNSET}
        if self.cache is None:
            if given:
                warnings.warn(
                    "EngineConfig(feature_cache=..., cache_capacity=..., "
                    "cache_ways=...) is deprecated; pass "
                    "cache=CacheConfig(enabled=..., capacity=..., ways=...)",
                    DeprecationWarning,
                    stacklevel=3,
                )
            cache = CacheConfig(
                enabled=bool(given.get("enabled", False)),
                capacity=given.get("capacity", None),
                ways=given.get("ways", 8),
            )
            object.__setattr__(self, "cache", cache)
        else:
            for key, val in given.items():
                have = getattr(self.cache, key)
                want = bool(val) if key == "enabled" else val
                if have != want:
                    raise ValueError(
                        f"cache=CacheConfig(...) and the deprecated "
                        f"{'feature_cache' if key == 'enabled' else 'cache_' + key} "
                        f"kwarg disagree ({have!r} vs {want!r}); drop the "
                        f"legacy kwarg"
                    )
        # mirror the resolved values into the legacy attrs so
        # ``dataclasses.replace`` round-trips without re-warning and old
        # readers of ``cfg.feature_cache`` etc. keep working
        object.__setattr__(self, "feature_cache", self.cache.enabled)
        object.__setattr__(self, "cache_capacity", self.cache.capacity)
        object.__setattr__(self, "cache_ways", self.cache.ways)

    @property
    def global_batch(self) -> int:
        return self.local_batch * self.num_pes

    @property
    def effective_kappa(self) -> Optional[int]:
        """RNG dependency window: iid forces κ=1 (fresh seed every step)."""
        return 1 if self.schedule == "iid" else self.kappa

    def with_mode(self, mode: str) -> "EngineConfig":
        """Same pipeline, other minibatching mode — the paper's controlled
        comparison at identical global batch size (§4.3)."""
        return replace(self, mode=mode)
