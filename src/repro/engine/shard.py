"""Multi-device cooperative execution: the engine under ``jax.shard_map``.

This is the promotion of :class:`repro.core.cooperative.ShardExecutor`
from a test-only wrapper to a first-class execution path.  A
:class:`ShardRunner` binds a cooperative :class:`MinibatchEngine` to a
real 1-D device mesh (:func:`repro.launch.mesh.make_coop_mesh`) and runs
the per-PE plan-construction and forward/backward bodies inside
``shard_map``, with ``jax.lax.all_to_all`` as the exchange primitive —
the paper's Algorithm 1 on actual devices instead of a vmap simulation.

Layout contract
---------------
Under :class:`SimExecutor` every plan leaf carries a stacked leading
``(P, ...)`` axis on ONE device.  The runner keeps that exact layout at
its boundary: :meth:`ShardRunner.plan_at` returns a stacked
:class:`CoopMinibatch` whose leaves are *device-sharded* along the mesh
axis.  Inside the ``shard_map`` body each PE sees its own ``(1, ...)``
shard, builds its local plan with :class:`ShardExecutor` (identity
``pe``, ``all_to_all`` exchange), and the runner re-attaches the leading
axis.  Because the per-PE code is byte-for-byte the same code SimExecutor
vmaps, integer plan state is **bit-identical** between the two executors
on identical κ-scheduled traces — that is the parity contract CI checks
(``tests/test_coop_shard.py``).  Floating-point loss/gradients agree to
reduction-order tolerance: the shard path sums per-PE partials and
``psum``s them, the sim path reduces one flat array.

Gradient sync is an *explicit* ``psum`` in :meth:`make_loss_and_grad`:
each PE differentiates its share of the global masked mean (its CE sum
over the psum'd valid count), then all-reduces the per-PE gradients.
The backward all-to-alls of Alg. 1 fall out of AD through
``all_to_all`` inside the body — no hand-written transposes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.cooperative import (
    CoopMinibatch,
    ShardExecutor,
    build_cooperative_minibatch,
)
from repro.core.graph import INVALID
from repro.launch.mesh import make_coop_mesh

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.engine.engine import MinibatchEngine


@dataclass
class ShardRunner:
    """Cooperative engine bound to a device mesh; one PE per device."""

    engine: "MinibatchEngine"
    mesh: Mesh

    @classmethod
    def for_engine(
        cls, engine: "MinibatchEngine", mesh: Optional[Mesh] = None
    ) -> "ShardRunner":
        cfg = engine.config
        if cfg.mode != "cooperative":
            raise ValueError(
                "ShardRunner needs a cooperative engine; independent mode "
                "is plain data parallelism (no all-to-all) — shard it with "
                "launch.shardings instead"
            )
        if not isinstance(engine.ex, ShardExecutor):
            raise ValueError(
                "engine was built with executor="
                f"{cfg.executor!r}; construct it with executor='shard'"
            )
        if mesh is None:
            mesh = make_coop_mesh(cfg.num_pes, axis_name=cfg.axis_name)
        if mesh.shape[cfg.axis_name] != cfg.num_pes:
            raise ValueError(
                f"mesh axis {cfg.axis_name!r} has size "
                f"{mesh.shape[cfg.axis_name]}, engine expects {cfg.num_pes}"
            )
        return cls(engine=engine, mesh=mesh)

    @property
    def axis(self) -> str:
        return self.engine.config.axis_name

    # ------------------------------------------------------------------
    # Per-PE plan construction (runs inside shard_map)
    # ------------------------------------------------------------------
    def _build_local(self, seeds_row: jax.Array, rng) -> CoopMinibatch:
        eng, cfg = self.engine, self.engine.config
        return build_cooperative_minibatch(
            eng.graph, eng.sampler, eng.part, seeds_row.reshape(-1), rng,
            cfg.num_layers, eng.caps, eng.ex, backend=cfg.plan_backend,
        )

    @cached_property
    def _plan_at_compiled(self):
        eng, ax = self.engine, self.axis

        def body(seeds_p, rng):
            mb = self._build_local(seeds_p, rng)
            return jax.tree.map(lambda x: x[None], mb)

        f = shard_map(
            body, mesh=self.mesh, in_specs=(P(ax), P()), out_specs=P(ax),
            check_rep=False,
        )

        def build(step):
            return f(eng._seed_batch_traced(step), eng.rng_state(step))

        return jax.jit(build)

    def plan_at(self, step) -> CoopMinibatch:
        """Stacked ``(P, ...)`` cooperative plan for ``step``, built by P
        devices cooperatively (id all-to-alls on the wire).  Same seeds,
        same RNG schedule, same layout as the SimExecutor ``plan_at`` —
        integer leaves are bit-identical."""
        return self._plan_at_compiled(jnp.asarray(step, jnp.int32))

    # ------------------------------------------------------------------
    # Training-step pieces (loss + explicitly psum-synced gradients)
    # ------------------------------------------------------------------
    def make_loss_and_grad(self, gnn_cfg, features: jax.Array, labels):
        """Build ``(params, step) -> (loss, grads)`` under shard_map.

        Per device: build the local plan, gather *owned* input features,
        run the cooperative forward (all-to-all redistribution between
        layers), differentiate the local share of the global masked-mean
        CE, then ``psum`` loss shares and gradients — the data-parallel
        gradient sync, over the same mesh axis as the all-to-alls.
        Matches the SimExecutor loss semantics exactly (same masked mean
        over the same B = b·P seed rows).
        """
        from repro.models.gnn import gnn_apply_cooperative
        from repro.train.metrics import masked_softmax_xent_parts

        eng, ax = self.engine, self.axis
        ex = eng.ex
        V = eng.graph.num_vertices
        labels = jnp.asarray(labels)

        def local_share(params, seeds_p, rng):
            mb = self._build_local(seeds_p, rng)
            h = features[jnp.clip(mb.input_ids, 0, V - 1)]
            H = jnp.where((mb.input_ids != INVALID)[:, None], h, 0.0)
            logits = gnn_apply_cooperative(
                params, gnn_cfg, ex, mb.layers, H, eng.caps.tilde_caps
            )
            y = labels[jnp.clip(mb.seed_ids, 0, V - 1)]
            valid = mb.seed_ids != INVALID
            s, n = masked_softmax_xent_parts(logits, y, valid)
            # this PE's share of the global masked mean: CE sum over the
            # *global* valid count; psum of shares == the global mean
            return s / jnp.maximum(jax.lax.psum(n, ax), 1).astype(s.dtype)

        def body(params, seeds_p, rng):
            share, grads = jax.value_and_grad(local_share)(
                params, seeds_p, rng
            )
            loss = jax.lax.psum(share, ax)   # global masked-mean CE
            grads = jax.lax.psum(grads, ax)  # explicit gradient sync
            return jax.tree.map(lambda x: x[None], (loss, grads))

        f = shard_map(
            body, mesh=self.mesh, in_specs=(P(), P(ax), P()),
            out_specs=P(ax), check_rep=False,
        )

        def loss_and_grad(params, step):
            step = jnp.asarray(step, jnp.int32)
            loss, grads = f(
                params, eng._seed_batch_traced(step), eng.rng_state(step)
            )
            # outputs are replicated across the axis; take PE 0's copy
            return loss[0], jax.tree.map(lambda x: x[0], grads)

        return loss_and_grad
