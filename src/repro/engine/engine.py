"""``MinibatchEngine`` — the unified minibatch-construction facade.

The paper's central comparison (§3.1–§3.2, Fig. 7) runs *the same*
training computation under two minibatching modes at identical global
batch size.  The engine makes that a config flag instead of two API
stacks: ``from_config`` derives capacity plans, partitions, executors,
and seed-batch generators from one :class:`EngineConfig`; ``build_plan``
returns a :class:`repro.engine.Plan` either way; ``apply_model`` owns
the single remaining mode dispatch (per-PE vmap vs all-to-all
redistribution).  The low-level builders (``build_minibatch``,
``build_cooperative_minibatch``) stay the stable kernel layer — the
engine never re-implements sampling, it only wires it.

Dependency schedules (§3.2 + A.7) are uniform too: ``iid`` (fresh seed
per step), ``smoothed`` (κ-window RNG interpolation), and ``nested``
(κ sub-batches carved from one group batch under a frozen group RNG).
``rng_state(step)`` is traceable, so one compiled train step serves the
whole schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cooperative import (
    CoopCapacityPlan,
    CoopMinibatch,
    Executor,
    ShardExecutor,
    SimExecutor,
    build_cooperative_minibatch,
)
from repro.core.dependent import NestedSchedule
from repro.core.feature_loader import FeatureStore
from repro.core.graph import Graph, INVALID
from repro.core.minibatch import CapacityPlan, Minibatch, build_minibatch
from repro.core.partition import Partition, make_partition
from repro.core.rng import DependentRNG, RNGState, _mix, hash_u32
from repro.core.samplers.base import Sampler, make_sampler
from repro.engine.config import EngineConfig
from repro.engine.plan import Plan
from repro.engine.stream import MinibatchStream
from repro.store.tiers import TieredFeatureStore


@jax.jit
def _hash_permute_rows(rows: jax.Array, z: jax.Array) -> jax.Array:
    """Row-wise hash-keyed permutation of an INVALID-padded pool table.

    Valid ids get uint32 keys (clamped below the sentinel key) and sort
    by them; INVALID entries pin to the key maximum so padding stays at
    every row's tail.  Stable argsort makes collisions deterministic.
    """
    salt = jnp.arange(rows.shape[0], dtype=jnp.uint32)[:, None]
    key = hash_u32(rows, z, salt)
    key = jnp.where(
        rows != INVALID,
        jnp.minimum(key, jnp.uint32(0xFFFFFFFE)),
        jnp.uint32(0xFFFFFFFF),
    )
    order = jnp.argsort(key, axis=1, stable=True)
    return jnp.take_along_axis(rows, order, axis=1)


@dataclass
class MinibatchEngine:
    """One object that turns (graph, config) into a stream of plans."""

    config: EngineConfig
    graph: Graph
    sampler: Sampler
    caps: CapacityPlan | CoopCapacityPlan
    ex: Optional[Executor] = None           # cooperative only
    part: Optional[Partition] = None        # cooperative only
    dataset: Optional[object] = None        # seeds come from train split if set
    store: Optional[FeatureStore] = None
    tiered: Optional[TieredFeatureStore] = None  # device cache tier, optional

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def from_config(
        cls, graph: Graph, config: EngineConfig, dataset=None
    ) -> "MinibatchEngine":
        """Derive capacities, partition, and executor from the config."""
        graph.validate()  # malformed CSR fails here, not mid-stream
        cfg, cap = config, config.capacity
        V = graph.num_vertices
        sampler = make_sampler(
            cfg.sampler, fanout=cfg.fanout, backend=cfg.plan_backend
        )
        if cfg.mode == "cooperative":
            caps = CoopCapacityPlan.geometric(
                cfg.local_batch, cfg.num_layers, cfg.fanout, V, cfg.num_pes,
                safety=cap.coop_safety, bucket_safety=cap.bucket_safety,
                round_to=cap.round_to,
            )
            pseed = cfg.seed if cfg.partition_seed is None else cfg.partition_seed
            part = make_partition(cfg.partition, graph, cfg.num_pes, seed=pseed)
            ex: Executor = (
                SimExecutor(cfg.num_pes)
                if cfg.executor == "sim"
                else ShardExecutor(cfg.num_pes, axis_name=cfg.axis_name)
            )
        else:
            caps = CapacityPlan.geometric(
                cfg.local_batch, cfg.num_layers, cfg.fanout, V,
                safety=cap.safety, round_to=cap.round_to,
            )
            part, ex = None, None
        store = FeatureStore(dataset.features) if dataset is not None else None
        tiered = None
        if dataset is not None and cfg.cache.enabled:
            cap = cfg.cache.capacity
            if cap is None:
                cap = max(cfg.cache.ways, V // 4)
            cap -= cap % cfg.cache.ways  # CLOCK sets need capacity % ways == 0
            tiered = TieredFeatureStore(
                dataset.features, capacity=cap, ways=cfg.cache.ways,
                num_pes=cfg.num_pes,
            )
        return cls(
            config=cfg, graph=graph, sampler=sampler, caps=caps, ex=ex,
            part=part, dataset=dataset, store=store, tiered=tiered,
        )

    # ------------------------------------------------------------------
    # RNG schedule
    # ------------------------------------------------------------------
    def _nested_sched(self) -> NestedSchedule:
        cfg = self.config
        return NestedSchedule(
            base_seed=cfg.seed, kappa=cfg.kappa, sub_batch_size=cfg.local_batch
        )

    def rng_at(self, step: int) -> DependentRNG:
        """Host-side RNG for ``step`` under the configured schedule."""
        cfg = self.config
        if cfg.schedule == "nested":
            return self._nested_sched().rng_for_group(step)  # frozen per group
        return DependentRNG(cfg.seed, cfg.effective_kappa, step)

    def rng_state(self, step) -> RNGState:
        """Traceable RNG state — ``step`` may be a traced int32 scalar, so
        a single compiled train step covers the whole κ schedule."""
        cfg = self.config
        if cfg.schedule == "nested":
            # traced mirror of NestedSchedule.rng_for_group(step).state —
            # pinned together by test_rng_state_matches_host_schedule
            base = jnp.uint32(cfg.seed & 0xFFFFFFFF)
            w = (jnp.asarray(step, jnp.int32) // cfg.kappa).astype(jnp.uint32)
            return RNGState(base + w, base + w, jnp.float32(0.0))
        return DependentRNG(cfg.seed, cfg.effective_kappa).state_at(step)

    # ------------------------------------------------------------------
    # Seed batches (device-resident, traceable)
    # ------------------------------------------------------------------
    def _seed_pool(self) -> np.ndarray:
        if self.dataset is not None:
            return np.asarray(self.dataset.train_ids)
        return np.arange(self.graph.num_vertices, dtype=np.int32)

    @cached_property
    def _owned_pools(self) -> list[np.ndarray]:
        # cached: the owner transfer + per-PE scans are O(V + P*|pool|),
        # too expensive to redo every training step
        pool = self._seed_pool()
        owner = np.asarray(self.part.owner)
        return [pool[owner[pool] == p] for p in range(self.config.num_pes)]

    @cached_property
    def _seed_rows(self) -> jax.Array:
        """(R, C) int32 device pool table, INVALID-padded rows.

        Cooperative: row p = PE p's owned train ids.  Independent nested:
        the global pool replicated P times (each PE permutes its own
        copy).  Independent otherwise: ONE global row — the first P·b
        entries of its per-step permutation are the global batch, which
        keeps the draw without-replacement *across* PEs.
        """
        cfg = self.config
        P, b = cfg.num_pes, cfg.local_batch
        if cfg.mode == "cooperative":
            rows = self._owned_pools
        elif cfg.schedule == "nested":
            rows = [self._seed_pool()] * P
        else:
            rows = [self._seed_pool()]
        need = cfg.kappa * b if cfg.schedule == "nested" else (
            P * b if len(rows) == 1 else b
        )
        C = max(need, max(len(r) for r in rows))
        out = np.full((len(rows), C), np.int32(INVALID), np.int32)
        for i, r in enumerate(rows):
            out[i, : len(r)] = np.asarray(r, np.int32)
        # first access may happen while tracing plan_at — keep the cached
        # table a concrete array, not a leaked tracer
        with jax.ensure_compile_time_eval():
            return jnp.asarray(out)

    def _seed_batch_traced(self, step) -> jax.Array:
        """(P, b) int32 seed rows for a (possibly traced) ``step``.

        Each draw is a hash-keyed permutation of the pool table: ids get
        uint32 sort keys from :func:`repro.core.rng.hash_u32` under a
        per-(step-or-group, row) salt; INVALID padding is pinned to the
        key maximum so it sorts last.  No host round-trips, so the whole
        seed schedule jits into ``plan_at`` / the train step.  Pools
        smaller than the draw pad with INVALID instead of raising.
        """
        cfg = self.config
        P, b = cfg.num_pes, cfg.local_batch
        step = jnp.asarray(step, jnp.int32)
        rows = self._seed_rows
        base = jnp.uint32(cfg.seed & 0xFFFFFFFF)
        if cfg.schedule == "nested":
            k = cfg.kappa
            g = (step // k).astype(jnp.uint32)
            perm = _hash_permute_rows(rows, _mix(g ^ base * jnp.uint32(0x9E3779B9)))
            i = step % k  # traced sub-batch index -> dynamic slice
            return jax.lax.dynamic_slice_in_dim(perm, i * b, b, axis=1)
        z = _mix(step.astype(jnp.uint32) ^ base * jnp.uint32(0x9E3779B9))
        perm = _hash_permute_rows(rows, z)
        if rows.shape[0] == 1:
            return perm[0, : P * b].reshape(P, b)
        return perm[:, :b]

    def seed_batch(self, step: int) -> np.ndarray:
        """(P, b) int32 seed rows for ``step`` (INVALID-padded short rows).

        Host-side materialization of :meth:`_seed_batch_traced` — same
        bits as the seeds ``plan_at``/the jitted train step consume.
        Independent: P·b ids drawn from the global pool without
        replacement.  Cooperative: row p holds only vertices PE p owns —
        the union is the global batch.  Nested schedules carve b-sized
        sub-batches out of a κ·b group batch redrawn every κ steps
        (§3.2).
        """
        return np.asarray(self._seed_batch_traced(int(step)))

    # ------------------------------------------------------------------
    # Plan construction
    # ------------------------------------------------------------------
    def build_plan(self, seeds, rng=None, step: int = 0) -> Plan:
        """Sample an L-layer plan from a seed frontier.

        ``seeds``: 1-D ``(b,)`` for a single independent plan (bit-equal
        to ``build_minibatch``) or stacked ``(P, b)`` for per-PE plans.
        ``rng`` defaults to the schedule's RNG at ``step``; pass a traced
        :class:`RNGState` from inside a jitted step to avoid retraces.
        ``config.plan_backend`` selects the frontier lowering (reference
        jnp algebra vs fused Pallas kernels) — outputs are bit-identical.
        """
        if rng is None:
            rng = self.rng_at(step)
        seeds = jnp.asarray(seeds, jnp.int32)
        cfg = self.config
        backend = cfg.plan_backend
        if cfg.mode == "cooperative":
            if cfg.executor == "shard":
                raise ValueError(
                    "build_plan runs per-PE bodies eagerly and cannot host "
                    "the shard executor's all_to_all outside shard_map; use "
                    "plan_at (routed through shard_runner) or executor='sim'"
                )
            return build_cooperative_minibatch(
                self.graph, self.sampler, self.part, seeds, rng,
                cfg.num_layers, self.caps, self.ex, backend=backend,
            )
        if seeds.ndim == 1:
            return build_minibatch(
                self.graph, self.sampler, seeds, rng, cfg.num_layers,
                self.caps, backend=backend,
            )
        build_one = lambda s: build_minibatch(
            self.graph, self.sampler, s, rng, cfg.num_layers, self.caps,
            backend=backend,
        )
        return jax.vmap(build_one)(seeds)

    @cached_property
    def _plan_at_compiled(self):
        def build(step):
            seeds = self._seed_batch_traced(step)
            return self.build_plan(seeds, rng=self.rng_state(step))

        return jax.jit(build)

    def plan_at(self, step) -> Plan:
        """Device-resident plan for ``step``: seed draw, schedule RNG and
        sampling compile into ONE jitted program with no host round-trip
        (``step`` is a dynamic int32, so a single trace serves the whole
        run).  Always builds the stacked ``(P, b)`` layout — identical to
        ``build_plan(seed_batch(step), rng=rng_state(step))``.

        With ``executor="shard"`` the build runs under ``shard_map`` on a
        real P-device mesh (id all-to-alls on the wire); integer plan
        state is bit-identical to the SimExecutor build.
        """
        if self.config.executor == "shard" and self.config.mode == "cooperative":
            return self.shard_runner.plan_at(step)
        return self._plan_at_compiled(jnp.asarray(step, jnp.int32))

    @cached_property
    def shard_runner(self):
        """Multi-device runner (``executor="shard"`` only): binds this
        engine to a P-device mesh and runs plan construction and the
        train-step loss under ``jax.shard_map``.  Requires ≥ P devices
        (on CPU: ``XLA_FLAGS=--xla_force_host_platform_device_count=P``
        before importing jax)."""
        from repro.engine.shard import ShardRunner

        return ShardRunner.for_engine(self)

    # ------------------------------------------------------------------
    # Feature loading — through the tiered store when configured
    # ------------------------------------------------------------------
    def gather_features(self, plan: Plan) -> jax.Array:
        """Input-layer embeddings ``H`` for ``plan``.

        With ``feature_cache`` on, the gather runs through the device
        CLOCK cache (bit-exact with the uncached path; misses fill from
        the host tier).  Dependent κ schedules drive its hit rate — the
        paper's §4.2 bandwidth saving, served rather than simulated.
        """
        if self.tiered is not None:
            return self.tiered.gather(plan.input_ids)
        if self.store is None:
            raise ValueError(
                "engine has no feature store; construct with a dataset"
            )
        return plan.gather_inputs(self.store)

    # ------------------------------------------------------------------
    # Model application — the one remaining mode dispatch
    # ------------------------------------------------------------------
    def apply_model(self, params, gnn_cfg, plan: Plan, H: jax.Array) -> jax.Array:
        """Seed logits from input embeddings ``H = plan.gather_inputs(...)``.

        Independent: per-PE bipartite compute (vmapped when stacked).
        Cooperative: Alg. 1 forward — all-to-all redistribution between
        layers; the backward all-to-alls fall out of AD.
        """
        from repro.models.gnn import gnn_apply, gnn_apply_cooperative

        if isinstance(plan, CoopMinibatch):
            return gnn_apply_cooperative(
                params, gnn_cfg, self.ex, plan.layers, H, self.caps.tilde_caps
            )
        if plan.input_ids.ndim > 1:  # stacked (P, ...) independent plans
            return jax.vmap(
                lambda layers, h: gnn_apply(params, gnn_cfg, layers, h)
            )(plan.layers, H)
        return gnn_apply(params, gnn_cfg, plan.layers, H)

    # ------------------------------------------------------------------
    # Streaming
    # ------------------------------------------------------------------
    def stream(
        self,
        num_steps: int,
        start_step: int = 0,
        prefetch: int = 2,
        fetch_features: bool = False,
    ) -> MinibatchStream:
        """Iterator over ``(plan, rng, step)`` items with host-side
        double-buffered prefetch (see :class:`MinibatchStream`).
        ``fetch_features`` loads input embeddings at dispatch time so
        tiered-cache fills overlap with the previous step's compute."""
        return MinibatchStream(
            self, num_steps, start_step, prefetch, fetch_features
        )
