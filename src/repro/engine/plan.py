"""The common ``Plan`` protocol both minibatch flavors satisfy.

A *plan* is the static-shape output of sampling: L bipartite layer
blocks, the input frontier whose features must load, and the seed
frontier whose labels are supervised.  ``Minibatch`` (independent, §2.3)
and ``CoopMinibatch`` (cooperative, §3.1) both satisfy this protocol, so
training loops, examples, and benchmarks can consume either without
mode branches — the engine owns the only mode dispatch (model apply).
"""
from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import jax


@runtime_checkable
class Plan(Protocol):
    """Uniform surface of a sampled L-layer minibatch plan."""

    layers: Sequence          # per-layer bipartite blocks (mode-specific)
    input_ids: jax.Array      # deepest frontier S^L — rows to fetch
    seed_ids: jax.Array       # seed frontier S^0 — rows to supervise

    def gather_inputs(self, store) -> jax.Array:
        """Load input-layer embeddings from a ``FeatureStore``-like object
        (anything with ``gather(ids) -> (..., d)`` masking INVALID rows)."""
        ...

    def stats(self) -> dict:
        """Vertex/edge/communication counts (Fig 3 / Table 7 quantities).

        Common keys: ``S{l}``, ``E{l}``, ``comm{l+1}``, ``inputs``.
        Cooperative plans add ``tilde{l+1}`` (request frontier sizes).
        Stacked plans report per-PE maxima.
        """
        ...
