"""Shared transformer building blocks (pure JAX, pjit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# logical sharding hints
#
# Model code never imports mesh objects; the launcher registers one and
# the model sprinkles ``shard_hint(x, "batch", None, ...)`` constraints so
# GSPMD keeps the batch dim sharded through reshapes (MoE groups, scan
# residuals) where propagation otherwise gives up.  With no registered
# mesh (unit tests, single-host runs) hints are no-ops.
# --------------------------------------------------------------------------
_LOGICAL_MESH = None


def set_logical_mesh(mesh) -> None:
    """Register (or clear, with None) the mesh used by ``shard_hint``."""
    global _LOGICAL_MESH
    _LOGICAL_MESH = mesh


def shard_hint(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x`` to (batch|model|None, ...) over the registered mesh."""
    mesh = _LOGICAL_MESH
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec

    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for dim, ax in zip(x.shape, logical):
        if ax == "batch" and batch:
            size = 1
            for a in batch:
                size *= mesh.shape[a]
            spec.append(batch if dim % size == 0 and dim > 1 else None)
        elif ax == "expert" and "data" in names:
            # expert-parallel activations: the expert dim of dispatched
            # token blocks lives on the data axis; the transition from
            # group-sharded tokens to expert-sharded blocks is then a
            # true EP all-to-all instead of a GSPMD replication.
            spec.append("data" if dim % mesh.shape["data"] == 0 else None)
        elif ax in ("model", "seq") and "model" in names:
            # "seq": Megatron-style sequence parallelism — the residual
            # stream's sequence dim shards over the model axis between
            # blocks (GSPMD inserts AG before attention / RS after),
            # shrinking saved activations model_size-fold.
            spec.append("model" if dim % mesh.shape["model"] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec))
    )


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (...,) -> (sin, cos) of shape (..., head_dim/2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half) * 2.0 / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., n_heads, head_dim); sin/cos broadcastable (..., head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron squared-ReLU
}


def mlp_apply(p: dict, x: jax.Array, activation: str, gated: bool) -> jax.Array:
    act = _ACTS[activation]
    if gated:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    return h @ p["w_down"]


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(d_ff))
    p = {
        "w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int | None) -> jax.Array:
    """(..., Q, K) boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m
