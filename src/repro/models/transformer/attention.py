"""Grouped-query attention: training (full-sequence) and decode (KV cache).

Conventions:
  x:       (B, S, d_model)
  q/k/v:   (B, S, H|KV, head_dim)
  cache:   dict(k=(B, S_max, KV, hd), v=...), one per attention layer
All masking is static-shape; decode masks by position index against the
current length, so one compiled ``serve_step`` serves every position.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer.config import ArchConfig
from repro.models.transformer.modules import apply_rope, causal_mask, rope_freqs, softcap


def init_attention(key, cfg: ArchConfig, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(d))
    so = float(1.0 / np.sqrt(H * hd))
    dt = cfg.jdtype
    return {
        "wq": jax.random.normal(ks[0], (d, H * hd), dt) * s,
        "wk": jax.random.normal(ks[1], (d, KV * hd), dt) * s,
        "wv": jax.random.normal(ks[2], (d, KV * hd), dt) * s,
        "wo": jax.random.normal(ks[3], (H * hd, d), dt) * so,
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _flash_attention(
    q: jax.Array,   # (B, S, H, hd) roped
    k: jax.Array,   # (B, S, H, hd) roped+repeated
    v: jax.Array,
    window: Optional[int],
    attn_softcap: Optional[float],
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanned over key blocks.

    Never materializes (S, S) scores — peak intermediate is
    (B, S, H, block_k), which keeps 32k-prefill inside HBM.  Causal /
    sliding-window masking applied per block.
    """
    B, S, H, hd = q.shape
    blk = min(block_k, S)
    assert S % blk == 0
    nb = S // blk
    scale = 1.0 / np.sqrt(hd)
    q_pos = jnp.arange(S)
    kb = jnp.moveaxis(k.reshape(B, nb, blk, H, hd), 1, 0)  # (nb,B,blk,H,hd)
    vb = jnp.moveaxis(v.reshape(B, nb, blk, H, hd), 1, 0)

    def step(carry, inp):
        acc, m, l = carry
        j, k_j, v_j = inp
        s = jnp.einsum("bqhd,bkhd->bqhk", q, k_j) * scale  # (B,S,H,blk)
        if attn_softcap:
            s = softcap(s, attn_softcap)
        k_pos = j * blk + jnp.arange(blk)
        ok = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(ok[None, :, None, :], s, -1e9)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, v_j)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, S, H, hd), jnp.float32)
    m0 = jnp.full((B, S, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step,
        (acc0, m0, l0),
        (jnp.arange(nb), kb.astype(jnp.float32), vb.astype(jnp.float32)),
    )
    return (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)


def _banded_local_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,
    v: jax.Array,
    window: int,
    attn_softcap: Optional[float],
) -> jax.Array:
    """Exact sliding-window attention in O(S·2W).

    Queries are blocked by window; block i attends key blocks {i-1, i}
    with an in-band causal/window mask — the standard TPU-friendly
    banded layout (no gather, all dense tiles).
    """
    B, S, H, hd = q.shape
    W = window
    assert S % W == 0, (S, W)
    nw = S // W
    scale = 1.0 / np.sqrt(hd)
    qb = q.reshape(B, nw, W, H, hd)
    kb = k.reshape(B, nw, W, H, hd)
    vb = v.reshape(B, nw, W, H, hd)
    # previous key/value block (zeros for the first)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B,nw,2W,H,hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    s = jnp.einsum("bnqhd,bnkhd->bnqhk", qb, k2) * scale  # (B,nw,W,H,2W)
    if attn_softcap:
        s = softcap(s, attn_softcap)
    q_pos = jnp.arange(W)[:, None]          # within-block query offset
    k_pos = jnp.arange(2 * W)[None, :] - W  # key offset relative to block
    ok = (k_pos <= q_pos) & (k_pos > q_pos - W)
    first_block = jnp.arange(nw) == 0       # no previous block to see
    ok_first = ok & (k_pos >= 0)
    mask = jnp.where(first_block[:, None, None], ok_first[None], ok[None])
    s = jnp.where(mask[None, :, :, None, :], s, -1e9)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bnqhk,bnkhd->bnqhd", w, v2)
    return out.reshape(B, S, H, hd)


def attention_train(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,                 # (B, S, d)
    positions: jax.Array,         # (S,) shared across batch rows
    window: Optional[int],        # None = global
) -> jax.Array:
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    B, S, _ = x.shape
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    sin, cos = rope_freqs(positions[None, :], hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    if window is not None and S > 2 * window and S % window == 0:
        out = _banded_local_attention(q, k, v, window, cfg.attn_softcap)
    else:
        out = _flash_attention(q, k, v, window, cfg.attn_softcap)
    return out.reshape(B, S, H * hd) @ p["wo"]


def attention_decode(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,            # (B, 1, d)
    cache: dict,             # {'k': (B, S_c, KV, hd), 'v': ...}
    pos: jax.Array,          # () current position (same for whole batch)
    window: Optional[int],
    ring: bool = False,
) -> tuple[jax.Array, dict]:
    """One-token attention against a KV cache.

    ``ring=True`` treats the cache as a rotating window buffer of length
    ``S_c == window``: slot ``pos % S_c`` is overwritten, slot ``i`` holds
    the key of absolute position ``pos - ((pos - i) mod S_c)`` (always
    within the window by construction) — O(window) memory for local
    layers even at 500k context.
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    S = cache["k"].shape[1]
    q = _split_heads(x @ p["wq"], H, hd)          # (B,1,H,hd)
    k_new = _split_heads(x @ p["wk"], KV, hd)
    v_new = _split_heads(x @ p["wv"], KV, hd)
    posb = jnp.broadcast_to(pos, x.shape[:1] + (1,))
    sin, cos = rope_freqs(posb, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)
    slot = pos % S if ring else pos
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0)
    )
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0)
    )
    new_cache = {"k": k, "v": v}
    kr = _repeat_kv(k, H // KV)
    vr = _repeat_kv(v, H // KV)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)  # (B,H,1,S)
    if cfg.attn_softcap:
        scores = softcap(scores, cfg.attn_softcap)
    idx = jnp.arange(S)
    if ring:
        k_pos = pos - ((pos - idx) % S)   # absolute position held by slot
        valid = k_pos >= 0
    else:
        valid = idx <= pos
        if window is not None:
            valid &= idx > pos - window
    scores = jnp.where(
        valid[None, None, None, :], scores, jnp.asarray(-1e9, scores.dtype)
    )
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vr)
    return out.reshape(*x.shape[:-1], H * hd) @ p["wo"], new_cache


def cross_attention(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,        # (B, S_dec, d)
    enc_out: jax.Array,  # (B, S_enc, d)
) -> jax.Array:
    """Whisper-style encoder-decoder cross attention (no mask, no RoPE)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"], H, hd)
    k = _repeat_kv(_split_heads(enc_out @ p["wk"], KV, hd), H // KV)
    v = _repeat_kv(_split_heads(enc_out @ p["wv"], KV, hd), H // KV)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return out.reshape(*x.shape[:-1], H * hd) @ p["wo"]
