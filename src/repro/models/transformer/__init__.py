from repro.models.transformer.config import ArchConfig
from repro.models.transformer.model import (
    init_lm,
    forward_train,
    forward_prefill,
    forward_decode,
    init_decode_state,
    prefill_decode,
)

__all__ = [
    "ArchConfig",
    "init_lm",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_decode_state",
    "prefill_decode",
]
