"""Architecture configuration for the assigned model pool.

One generic decoder implementation covers all six arch types via the
switches below; per-arch files in ``repro/configs`` instantiate it with
the exact published hyperparameters (citations in each file).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                     # query heads (0 for pure SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention layout
    head_dim: Optional[int] = None     # default d_model // num_heads
    layer_pattern: tuple[str, ...] = ("global",)  # cycled: global|local|ssm|hybrid
    window: int = 4096                 # sliding-window size for 'local'
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None    # gemma2-style tanh capping
    logit_softcap: Optional[float] = None

    # mlp
    activation: str = "silu"           # silu | gelu | relu2
    gated_mlp: bool = True             # SwiGLU/GeGLU vs plain

    # moe
    num_experts: int = 0               # 0 = dense MLP
    moe_top_k: int = 1
    moe_capacity_factor: float = 1.25
    # routing groups: top-k + capacity + sort run independently inside
    # each group (group dim = data shards) so dispatch stays shard-local
    # under GSPMD instead of becoming a global argsort.
    moe_groups: int = 1

    # ssm (mamba2 SSD)
    ssm_state: int = 0                 # N; 0 = no ssm
    ssm_heads: int = 0                 # SSD heads (default d_inner/64)
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # modality / structure
    frontend: Optional[str] = None     # None | 'audio' | 'vision'
    num_prefix_tokens: int = 0         # stub patch/frame prefix length
    enc_dec: bool = False              # whisper: cross-attend to encoder out
    enc_len: int = 1500                # encoder output length (audio frames)

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "float32"             # params/activations dtype name
    remat: bool = True                 # per-layer activation checkpointing
    seq_shard: bool = False            # sequence-parallel residual stream
                                       # (§Perf hillclimb lever)

    # paper-technique transfer (DESIGN.md §4): deduplicated vocab-sharded
    # embedding gather with all-to-all — cooperative feature loading.
    cooperative_embed: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    def layer_kind(self, l: int) -> str:
        return self.layer_pattern[l % len(self.layer_pattern)]

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer attends globally over the full sequence."""
        kinds = {self.layer_kind(l) for l in range(self.num_layers)}
        return "global" not in kinds or self.arch_type == "ssm"

    def reduced(self, **overrides) -> "ArchConfig":
        """2-layer, narrow smoke variant of the same family."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else None,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            window=64,
            ssm_chunk=16,
            enc_len=32,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)


# canonical FLOP count helpers ------------------------------------------------
def param_count(cfg: ArchConfig) -> int:
    """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
    d, L = cfg.d_model, cfg.num_layers
    n = cfg.vocab_size * d  # embed (tied head)
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d
    for l in range(L):
        kind = cfg.layer_kind(l)
        if kind in ("global", "local", "hybrid"):
            q = d * cfg.num_heads * cfg.hd
            kv = 2 * d * cfg.num_kv_heads * cfg.hd
            o = cfg.num_heads * cfg.hd * d
            n += q + kv + o
        if kind in ("ssm", "hybrid") or cfg.arch_type == "ssm":
            di = cfg.d_inner
            n += d * 2 * di  # in_proj (x, z)
            n += di * (2 * cfg.ssm_state + cfg.n_ssm_heads)  # B, C, dt proj
            n += di * d  # out_proj
        if cfg.d_ff:
            mult = 3 if cfg.gated_mlp else 2
            if cfg.num_experts:
                n += cfg.num_experts * mult * d * cfg.d_ff + d * cfg.num_experts
            else:
                n += mult * d * cfg.d_ff
        if cfg.enc_dec:
            n += 4 * d * d  # cross-attention
    return int(n)


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    if not cfg.num_experts:
        return param_count(cfg)
    full = param_count(cfg)
    mult = 3 if cfg.gated_mlp else 2
    expert_params = cfg.num_layers * cfg.num_experts * mult * cfg.d_model * cfg.d_ff
    active_experts = cfg.num_layers * cfg.moe_top_k * mult * cfg.d_model * cfg.d_ff
    return int(full - expert_params + active_experts)
