"""Mixture-of-Experts MLP with sort-based capacity dispatch.

Tokens are routed top-k, grouped per expert by a stable sort (the same
owner-bucketing pattern as ``cooperative._bucketize`` — the paper's
communication structure reused for expert dispatch, DESIGN.md §4),
processed as dense (E, C, d) batched matmuls (MXU-friendly), and
combined back with router weights.  Over-capacity tokens are dropped
(standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer.config import ArchConfig
from repro.models.transformer.modules import _ACTS


def init_moe(key, cfg: ArchConfig) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    s_in, s_out = float(1.0 / np.sqrt(d)), float(1.0 / np.sqrt(f))
    p = {
        "router": jax.random.normal(ks[0], (d, E), dt) * s_in,
        "w_up": jax.random.normal(ks[1], (E, d, f), dt) * s_in,
        "w_down": jax.random.normal(ks[2], (E, f, d), dt) * s_out,
    }
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(ks[3], (E, d, f), dt) * s_in
    return p


def moe_apply(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    Routing/dispatch runs per *group* (``cfg.moe_groups``, aligned with
    the data shards at launch time): the argsort/capacity logic then
    never crosses shard boundaries, so GSPMD keeps dispatch local and
    only the expert matmuls touch the model axis.
    """
    from repro.models.transformer.modules import shard_hint

    B, S, d = x.shape
    G = cfg.moe_groups if B % max(cfg.moe_groups, 1) == 0 else 1
    if G > 1:
        xg = shard_hint(x.reshape(G, (B // G) * S, d), "batch", None, None)
        out, aux = jax.vmap(
            lambda xx: _moe_group(p, cfg, xx), out_axes=(0, 0)
        )(xg)
        out = shard_hint(out, "batch", None, None)
        return out.reshape(B, S, d), jnp.mean(aux)
    out, aux = _moe_group(p, cfg, x.reshape(B * S, d))
    return out.reshape(B, S, d), aux


def _moe_group(p: dict, cfg: ArchConfig, xf: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(T, d) -> ((T, d), aux)."""
    T, d = xf.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    logits = (xf @ p["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)               # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(expert[:, 0], E, dtype=jnp.float32), axis=0
    )
    density_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * density_prob)

    C = int(np.ceil(T * k / E * cfg.moe_capacity_factor))
    C = max(8, -(-C // 8) * 8)

    # flatten (token, slot) assignments and group by expert via stable sort
    flat_expert = expert.reshape(-1)                     # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E + 1))
    rank = jnp.arange(T * k) - group_start[jnp.clip(sorted_e, 0, E)]
    ok = rank < C
    slot = jnp.where(ok, sorted_e * C + rank, E * C)     # park overflow

    table_tok = (
        jnp.full((E * C + 1,), -1, jnp.int32)
        .at[slot]
        .set(jnp.where(ok, flat_token[order].astype(jnp.int32), -1))[: E * C]
        .reshape(E, C)
    )
    table_gate = (
        jnp.zeros((E * C + 1,), jnp.float32)
        .at[slot]
        .set(jnp.where(ok, flat_gate[order], 0.0))[: E * C]
        .reshape(E, C)
    )

    from repro.models.transformer.modules import shard_hint

    valid = table_tok >= 0
    xg = xf[jnp.clip(table_tok, 0)]                      # (E, C, d)
    xg = jnp.where(valid[..., None], xg, 0.0)
    # EP hint: expert blocks shard over data (a no-op if E % data != 0);
    # the group->expert reshard then lowers to an all-to-all.
    xg = shard_hint(xg, "expert", None, None)
    act = _ACTS[cfg.activation]
    if cfg.gated_mlp:
        h = act(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", xg, p["w_up"]
        )
    else:
        h = act(jnp.einsum("ecd,edf->ecf", xg, p["w_up"]))
    yg = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E, C, d)
    yg = shard_hint(yg, "expert", None, None)
    yg = yg * table_gate[..., None].astype(yg.dtype)

    out = (
        jnp.zeros((T + 1, d), yg.dtype)
        .at[jnp.where(valid, table_tok, T).reshape(-1)]
        .add(yg.reshape(-1, d))[:T]
    )
    return out.astype(xf.dtype), aux
