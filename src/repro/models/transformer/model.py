"""Generic decoder LM covering the assigned architecture pool.

Block kinds (``cfg.layer_pattern``):
  global        full causal GQA attention
  local         sliding-window GQA attention (window = cfg.window)
  ssm           Mamba-2 SSD mixer (attention-free)
  hybrid        parallel attention (windowed) + SSD heads, mean-fused (hymba)
  hybrid_global hybrid with full attention (hymba's few global layers)

MLP: dense (SwiGLU / GeGLU / squared-ReLU) or MoE (grok-1, llama4-scout).
Frontends (audio/vision) are stubs per the brief: callers pass
precomputed frame/patch embeddings; whisper additionally cross-attends
to a stub-encoded audio context (enc-dec).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer.attention import (
    attention_decode,
    attention_train,
    cross_attention,
    init_attention,
)
from repro.models.transformer.config import ArchConfig
from repro.models.transformer.moe import init_moe, moe_apply
from repro.models.transformer.modules import (
    init_mlp,
    mlp_apply,
    rms_norm,
    shard_hint,
    softcap,
)
from repro.models.transformer.ssm import (
    init_ssm,
    init_ssm_state,
    ssm_decode,
    ssm_train,
)

# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _num_units(cfg: ArchConfig) -> tuple[int, int]:
    """Layers are grouped into scan units of one pattern period each.

    Returns (n_units, tail): ``n_units`` full periods are executed with
    ``lax.scan`` (sequential buffer reuse — the production layout, also
    ~P_len× smaller HLO); ``tail`` leftover layers run unrolled.
    """
    p = len(cfg.layer_pattern)
    return cfg.num_layers // p, cfg.num_layers % p


def _init_layer(key, cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lp: dict = {"norm1": jnp.zeros((d,), cfg.jdtype)}
    if kind in ("global", "local", "hybrid", "hybrid_global"):
        lp["attn"] = init_attention(k1, cfg)
    if kind in ("ssm", "hybrid", "hybrid_global"):
        lp["ssm"] = init_ssm(k2, cfg)
        if kind != "ssm":
            lp["norm_ssm"] = jnp.zeros((d,), cfg.jdtype)
    if cfg.enc_dec:
        lp["cross"] = init_attention(k3, cfg, cross=True)
        lp["norm_cross"] = jnp.zeros((d,), cfg.jdtype)
    if cfg.d_ff:
        lp["norm2"] = jnp.zeros((d,), cfg.jdtype)
        if cfg.num_experts:
            lp["moe"] = init_moe(k4, cfg)
        else:
            lp["mlp"] = init_mlp(k4, d, cfg.d_ff, cfg.gated_mlp, cfg.jdtype)
    return lp


def init_lm(key: jax.Array, cfg: ArchConfig) -> dict:
    """Parameter pytree.

    ``blocks[s]`` holds the parameters of pattern-slot ``s`` stacked over
    the ``n_units`` scan iterations (leading axis U); ``tail`` holds the
    unrolled leftover layers (pattern periods that do not divide L).
    """
    d, V = cfg.d_model, cfg.vocab_size
    key, ke = jax.random.split(key)
    params: dict = {
        "embed": jax.random.normal(ke, (V, d), cfg.jdtype) * 0.02,
        "final_norm": jnp.zeros((d,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        key, ku = jax.random.split(key)
        params["unembed"] = jax.random.normal(ku, (d, V), cfg.jdtype) * 0.02
    n_units, tail = _num_units(cfg)
    p_len = len(cfg.layer_pattern)
    blocks = []
    for s in range(p_len):
        kind = cfg.layer_pattern[s]
        per_unit = []
        for u in range(n_units):
            key, kl = jax.random.split(key)
            per_unit.append(_init_layer(kl, cfg, kind))
        if per_unit:
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_unit))
    params["blocks"] = blocks
    tail_layers = []
    for t in range(tail):
        key, kl = jax.random.split(key)
        tail_layers.append(_init_layer(kl, cfg, cfg.layer_pattern[t]))
    params["tail"] = tail_layers
    return params


def layer_params(params: dict, cfg: ArchConfig, l: int) -> dict:
    """Per-layer view of the stacked layout (decode path, tests)."""
    n_units, _ = _num_units(cfg)
    p_len = len(cfg.layer_pattern)
    if l < n_units * p_len:
        u, s = divmod(l, p_len)
        return jax.tree.map(lambda x: x[u], params["blocks"][s])
    return params["tail"][l - n_units * p_len]


def _attn_window(cfg: ArchConfig, kind: str) -> Optional[int]:
    return cfg.window if kind in ("local", "hybrid") else None


def _unembed(params: dict, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h @ w
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


# --------------------------------------------------------------------------
# training / prefill forward (full sequence)
# --------------------------------------------------------------------------
def forward_hidden(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,                      # (B, S_text)
    prefix_embeds: Optional[jax.Array] = None,  # (B, n_prefix, d) vlm/audio
    enc_out: Optional[jax.Array] = None,        # (B, enc_len, d) whisper
) -> tuple[jax.Array, jax.Array]:
    """Returns (final-norm hidden states (B, S_total, d), moe_aux scalar).

    Kept separate from the unembedding so training can compute the loss
    in sequence chunks — materializing full (B, S, V) logits at the
    assigned batch shapes would be O(100 TB) (see steps.lm_loss).
    """
    if cfg.cooperative_embed and tokens.size > cfg.vocab_size:
        # Cooperative embedding gather (DESIGN.md §4) — the paper's
        # deduplicated feature loading applied to the vocab table: the
        # global batch requests each *unique* token id once from the
        # vocab-sharded table (static bound: V rows ≪ B·S token slots),
        # then expands locally.  Backward dedups the scatter-add the
        # same way (AD of unique+gather).
        flat = tokens.reshape(-1)
        # pad with the max id so the padded vector stays sorted (the
        # searchsorted below requires it)
        uniq = jnp.unique(
            flat, size=cfg.vocab_size, fill_value=cfg.vocab_size - 1
        )
        rows = params["embed"][uniq]
        idx = jnp.searchsorted(uniq, flat)
        h = rows[idx].reshape(*tokens.shape, -1)
    else:
        h = params["embed"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.arange(S)

    def block(lp, h, kind):
        # keep the residual stream batch-sharded through every reshape;
        # optionally also sequence-sharded over the model axis (Megatron
        # sequence parallelism — §Perf)
        h = shard_hint(h, "batch", "seq" if cfg.seq_shard else None, None)
        a2 = jnp.zeros((), jnp.float32)
        if kind == "ssm":
            h = h + ssm_train(lp["ssm"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps))
        elif kind in ("hybrid", "hybrid_global"):
            a = attention_train(
                lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
                positions, _attn_window(cfg, kind),
            )
            s = ssm_train(lp["ssm"], cfg, rms_norm(h, lp["norm_ssm"], cfg.norm_eps))
            h = h + 0.5 * (a + s)
        else:
            h = h + attention_train(
                lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
                positions, _attn_window(cfg, kind),
            )
        if cfg.enc_dec and enc_out is not None:
            h = h + cross_attention(
                lp["cross"], cfg, rms_norm(h, lp["norm_cross"], cfg.norm_eps), enc_out
            )
        if cfg.d_ff:
            x2 = rms_norm(h, lp["norm2"], cfg.norm_eps)
            if cfg.num_experts:
                y, a2 = moe_apply(lp["moe"], cfg, x2)
                h = h + y
            else:
                h = h + mlp_apply(lp["mlp"], x2, cfg.activation, cfg.gated_mlp)
        return h, a2

    n_units, tail = _num_units(cfg)
    pattern = cfg.layer_pattern

    def unit_body(carry, unit_params):
        h, aux = carry
        for s, lp in enumerate(unit_params):
            h, a2 = block(lp, h, pattern[s])
            aux = aux + a2
        return (h, aux), None

    carry = (h, jnp.zeros((), jnp.float32))
    if n_units:
        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        carry, _ = jax.lax.scan(body, carry, params["blocks"])
    h, aux = carry
    for t, lp in enumerate(params["tail"]):
        fn = jax.checkpoint(block, static_argnums=(2,)) if cfg.remat else block
        h, a2 = fn(lp, h, pattern[t])
        aux = aux + a2
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux / max(cfg.num_layers, 1)


def forward_train(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Full logits (B, S_total, V) — small-scale/eval use only."""
    h, aux = forward_hidden(params, cfg, tokens, prefix_embeds, enc_out)
    return _unembed(params, cfg, h), aux


# --------------------------------------------------------------------------
# decode state
# --------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Zero KV/SSM caches for a ``max_len`` decode session."""
    layers = []
    KV, hd = cfg.num_kv_heads, cfg.hd
    for l in range(cfg.num_layers):
        kind = cfg.layer_kind(l)
        st: dict = {}
        if kind in ("global", "hybrid_global"):
            st["kv"] = {
                "k": jnp.zeros((batch, max_len, KV, hd), cfg.jdtype),
                "v": jnp.zeros((batch, max_len, KV, hd), cfg.jdtype),
            }
        elif kind in ("local", "hybrid"):
            W = min(cfg.window, max_len)
            st["kv"] = {
                "k": jnp.zeros((batch, W, KV, hd), cfg.jdtype),
                "v": jnp.zeros((batch, W, KV, hd), cfg.jdtype),
            }
        if kind in ("ssm", "hybrid", "hybrid_global"):
            st["ssm"] = init_ssm_state(cfg, batch)
        layers.append(st)
    state = {"pos": jnp.zeros((), jnp.int32), "layers": layers}
    if cfg.enc_dec:
        state["enc_out"] = jnp.zeros((batch, cfg.enc_len, cfg.d_model), cfg.jdtype)
    return state


def _is_ring(cfg: ArchConfig, kind: str, cache_len: int) -> bool:
    return kind in ("local", "hybrid") and cache_len <= cfg.window


# --------------------------------------------------------------------------
# decode forward (one token)
# --------------------------------------------------------------------------
def forward_decode(
    params: dict, cfg: ArchConfig, state: dict, token: jax.Array  # (B, 1)
) -> tuple[jax.Array, dict]:
    """One-token step with KV/SSM caches: returns (logits (B, V), state)."""
    h = params["embed"][token]  # (B, 1, d)
    pos = state["pos"]
    new_layers = []
    for l in range(cfg.num_layers):
        lp = layer_params(params, cfg, l)
        kind = cfg.layer_kind(l)
        st = dict(state["layers"][l])
        if kind == "ssm":
            y, st["ssm"] = ssm_decode(
                lp["ssm"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps), st["ssm"]
            )
            h = h + y
        elif kind in ("hybrid", "hybrid_global"):
            ring = _is_ring(cfg, kind, st["kv"]["k"].shape[1])
            a, st["kv"] = attention_decode(
                lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
                st["kv"], pos, _attn_window(cfg, kind), ring=ring,
            )
            s, st["ssm"] = ssm_decode(
                lp["ssm"], cfg, rms_norm(h, lp["norm_ssm"], cfg.norm_eps), st["ssm"]
            )
            h = h + 0.5 * (a + s)
        else:
            ring = _is_ring(cfg, kind, st["kv"]["k"].shape[1])
            a, st["kv"] = attention_decode(
                lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
                st["kv"], pos, _attn_window(cfg, kind), ring=ring,
            )
            h = h + a
        if cfg.enc_dec:
            h = h + cross_attention(
                lp["cross"], cfg, rms_norm(h, lp["norm_cross"], cfg.norm_eps),
                state["enc_out"],
            )
        if cfg.d_ff:
            x2 = rms_norm(h, lp["norm2"], cfg.norm_eps)
            if cfg.num_experts:
                y, _ = moe_apply(lp["moe"], cfg, x2)
                h = h + y
            else:
                h = h + mlp_apply(lp["mlp"], x2, cfg.activation, cfg.gated_mlp)
        new_layers.append(st)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, h)[:, 0, :]  # (B, V)
    new_state = dict(state)
    new_state["layers"] = new_layers
    new_state["pos"] = pos + 1
    return logits, new_state


# --------------------------------------------------------------------------
# prefill: fill the decode caches over a whole prompt in ONE compiled call
# --------------------------------------------------------------------------
def prefill_decode(
    params: dict, cfg: ArchConfig, state: dict, tokens: jax.Array  # (B, S0)
) -> tuple[jax.Array, dict]:
    """Batched prompt prefill against the decode caches.

    Scans :func:`forward_decode` over the prompt positions inside one
    program, so a jitted caller pays ONE dispatch for the whole prompt
    instead of S0 python-loop round trips — and because the scan body IS
    the per-token decode step, the resulting caches, state and logits
    are bit-identical to stepping ``serve_step`` token by token (pinned
    by ``tests/test_transformer_units.py``).  Returns the last prompt
    position's logits ``(B, V)`` and the advanced state.
    """

    def body(st, tok):  # tok: (B,)
        logits, st = forward_decode(params, cfg, st, tok[:, None])
        return st, logits

    state, logits = jax.lax.scan(body, state, jnp.moveaxis(tokens, 1, 0))
    return logits[-1], state


# --------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the decode caches
# --------------------------------------------------------------------------
def forward_prefill(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Prefill forward; returns (last-position logits (B, V), moe aux).

    Production serving would also emit the KV caches; for the dry-run we
    lower the compute-dominant path (full forward) — decode shapes lower
    ``forward_decode`` against a pre-sized cache instead.
    """
    logits, aux = forward_train(params, cfg, tokens, prefix_embeds, enc_out)
    return logits[:, -1, :], aux
