"""Mamba-2 SSD (state-space duality) sequence mixer [arXiv:2405.21060].

Chunked matmul formulation: within-chunk terms are dense (MXU-friendly)
masked matmuls; cross-chunk recurrence is a ``lax.scan`` carrying the
(B, H, P, N) state.  Single B/C group shared across heads (Mamba-2
default ngroups=1).

Decode is the O(1) recurrent step:  h <- exp(dt·A) h + (dt·x) ⊗ B;
y = C·h + D·x, with a rolling causal-conv state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer.config import ArchConfig


def init_ssm(key, cfg: ArchConfig) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    assert H * P == di, (di, H)
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    s = float(1.0 / np.sqrt(d))
    conv_dim = di + 2 * N
    return {
        # fused input projection: [z (di) | x (di) | B (N) | C (N) | dt (H)]
        "w_in": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H), dt) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dt) * 0.1,
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (H,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": jax.random.normal(ks[3], (di, d), dt) * float(1.0 / np.sqrt(di)),
    }


def _split_in(p, cfg: ArchConfig, u: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = u @ p["w_in"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * N :]
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """(B, S, C) depthwise causal conv, kernel (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out)


def ssm_train(p: dict, cfg: ArchConfig, u: jax.Array) -> jax.Array:
    """(B, S, d_model) -> (B, S, d_model); chunked SSD scan."""
    B, S, _ = u.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    z, xbc, dt_raw = _split_in(p, cfg, u)
    xbc = _causal_conv(xbc, p["conv_w"])
    x = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di : di + N]                     # (B,S,N)
    Cm = xbc[..., di + N :]                        # (B,S,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                        # (H,) negative

    la = dt * A                                     # (B,S,H) log decay
    xb = x.astype(jnp.float32) * dt[..., None]      # dt-scaled input

    # chunk views
    la_c = la.reshape(B, nc, Q, H)
    cum = jnp.cumsum(la_c, axis=2)                  # (B,nc,Q,H)
    xb_c = xb.reshape(B, nc, Q, H, P)
    B_c = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nc, Q, N).astype(jnp.float32)

    # ---- intra-chunk (dense masked matmuls) ----
    G = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)     # (B,nc,Q,Q)
    # clamp the exponent at 0: exact on the causal (i >= j) region, and
    # prevents exp-overflow -> NaN gradients through the masked i < j
    # entries (la <= 0 so cum is nonincreasing within a chunk)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = G[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xb_c)

    # ---- chunk summaries + cross-chunk recurrence ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, B_c, xb_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)
    in_decay = jnp.exp(cum)                                     # decay start->i

    def chunk_step(h, inp):
        S_cc, cd, Ci, indec = inp
        # contribution of the carried state to every position in the chunk
        y_int = jnp.einsum("bin,bhpn,bih->bihp", Ci, h, indec)
        h_new = cd[:, :, None, None] * h + S_cc
        return h_new, y_int

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    scan_in = (
        jnp.moveaxis(S_c, 1, 0),           # (nc,B,H,P,N)
        jnp.moveaxis(chunk_decay, 1, 0),   # (nc,B,H)
        jnp.moveaxis(C_c, 1, 0),           # (nc,B,Q,N)
        jnp.moveaxis(in_decay, 1, 0),      # (nc,B,Q,H)
    )
    _, y_inter = jax.lax.scan(chunk_step, h0, scan_in)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B,nc,Q,H,P)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(u.dtype)
    return (y * jax.nn.silu(z)) @ p["w_out"]


def init_ssm_state(cfg: ArchConfig, batch: int) -> dict:
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    conv_dim = di + 2 * N
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cfg.jdtype),
    }


def ssm_decode(p: dict, cfg: ArchConfig, u: jax.Array, state: dict):
    """One-token step: u (B, 1, d) -> (y (B, 1, d), new state)."""
    B = u.shape[0]
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    z, xbc, dt_raw = _split_in(p, cfg, u)
    z, xbc, dt_raw = z[:, 0], xbc[:, 0], dt_raw[:, 0]

    # rolling causal conv
    hist = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"])
    xbc = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :]

    x = xbc[..., :di].reshape(B, H, P).astype(jnp.float32)
    Bm = xbc[..., di : di + N].astype(jnp.float32)
    Cm = xbc[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                          # (B,H)
    xdt = x * dt[..., None]                                          # (B,H,P)
    h = state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, Bm
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + p["D"][None, :, None] * x
    y = y.reshape(B, 1, di).astype(u.dtype)
    out = (y * jax.nn.silu(z[:, None, :])) @ p["w_out"]
    return out, {"h": h, "conv": new_conv}
