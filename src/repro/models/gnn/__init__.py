from repro.models.gnn.layers import (
    GNNConfig,
    init_gnn,
    gnn_apply,
    gnn_apply_cooperative,
)

__all__ = ["GNNConfig", "init_gnn", "gnn_apply", "gnn_apply_cooperative"]
