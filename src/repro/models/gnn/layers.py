"""GNN models over padded bipartite layer blocks.

Every layer consumes ``H~`` — embeddings indexed by the *request-side*
frontier (for Independent Minibatching that's simply ``S^{l+1}``; for
Cooperative it's ``S~^{l+1}`` after the all-to-all) — plus the layer's
local indices (``self_idx``, ``nbr_idx``, ``mask``), and emits embeddings
for the layer's destination frontier ``S^l``.  The *same* model code
therefore runs under both minibatching modes; only the embedding
provider differs (DESIGN.md §2).

Models: gcn | sage | gat | rgcn — the paper evaluates GCN (papers100M),
R-GCN (mag240M) and GAT (§4.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"           # gcn | sage | gat | rgcn
    num_layers: int = 3
    in_dim: int = 64
    hidden_dim: int = 256
    num_classes: int = 16
    num_heads: int = 4           # gat
    num_relations: int = 1       # rgcn
    dtype: jnp.dtype = jnp.float32


def _glorot(key, shape, dtype):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def init_gnn(key: jax.Array, cfg: GNNConfig) -> dict:
    """Parameter pytree: params['layers'][l] is one layer's dict."""
    # plan layer l computes H^l from H^{l+1}: layer L-1 consumes raw
    # features, layer 0 emits class logits.
    layers = []
    for l in range(cfg.num_layers):
        d_in = cfg.in_dim if l == cfg.num_layers - 1 else cfg.hidden_dim
        d_out = cfg.num_classes if l == 0 else cfg.hidden_dim
        key, *ks = jax.random.split(key, 6)
        if cfg.model == "gcn":
            p = {"w": _glorot(ks[0], (d_in, d_out), cfg.dtype),
                 "b": jnp.zeros((d_out,), cfg.dtype)}
        elif cfg.model == "sage":
            p = {
                "w_self": _glorot(ks[0], (d_in, d_out), cfg.dtype),
                "w_nbr": _glorot(ks[1], (d_in, d_out), cfg.dtype),
                "b": jnp.zeros((d_out,), cfg.dtype),
            }
        elif cfg.model == "gat":
            h = cfg.num_heads
            dh = max(1, d_out // h)
            p = {
                "w": _glorot(ks[0], (d_in, h * dh), cfg.dtype),
                "a_src": _glorot(ks[1], (h, dh, 1), cfg.dtype)[..., 0],
                "a_dst": _glorot(ks[2], (h, dh, 1), cfg.dtype)[..., 0],
                "w_out": _glorot(ks[3], (h * dh, d_out), cfg.dtype),
                "b": jnp.zeros((d_out,), cfg.dtype),
            }
        elif cfg.model == "rgcn":
            p = {
                "w_self": _glorot(ks[0], (d_in, d_out), cfg.dtype),
                "w_rel": _glorot(ks[1], (cfg.num_relations, d_in, d_out), cfg.dtype),
                "b": jnp.zeros((d_out,), cfg.dtype),
            }
        else:
            raise ValueError(f"unknown gnn model {cfg.model!r}")
        layers.append(p)
    return {"layers": layers}


def _gather(Ht: jax.Array, idx: jax.Array) -> jax.Array:
    """Row gather with -1 -> zeros."""
    out = Ht[jnp.clip(idx, 0)]
    return jnp.where((idx >= 0)[..., None], out, 0.0)


def _masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    s = jnp.sum(jnp.where(mask[..., None], x, 0.0), axis=-2)
    n = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1)
    return s / n


def layer_apply(
    p: dict,
    cfg: GNNConfig,
    l: int,
    Ht: jax.Array,
    self_idx: jax.Array,
    nbr_idx: jax.Array,
    mask: jax.Array,
    etypes,
) -> jax.Array:
    """One bipartite GNN layer: (cap_tilde, d_in) -> (cap_l, d_out)."""
    # plan layer 0 emits logits (no activation); deeper layers use ReLU
    act = (lambda x: x) if l == 0 else jax.nn.relu
    h_self = _gather(Ht, self_idx)              # (n, d_in)
    h_nbr = _gather(Ht, nbr_idx)                # (n, w, d_in)
    if cfg.model == "gcn":
        # mean over {self} ∪ N(s)
        deg = jnp.sum(mask, axis=-1, keepdims=True) + 1
        agg = (jnp.sum(jnp.where(mask[..., None], h_nbr, 0.0), -2) + h_self) / deg
        return act(agg @ p["w"] + p["b"])
    if cfg.model == "sage":
        agg = _masked_mean(h_nbr, mask)
        return act(h_self @ p["w_self"] + agg @ p["w_nbr"] + p["b"])
    if cfg.model == "gat":
        h = cfg.num_heads
        z_self = (h_self @ p["w"]).reshape(*h_self.shape[:-1], h, -1)   # (n,h,dh)
        z_nbr = (h_nbr @ p["w"]).reshape(*h_nbr.shape[:-1], h, -1)     # (n,w,h,dh)
        e_dst = jnp.einsum("nhd,hd->nh", z_self, p["a_dst"])           # (n,h)
        e_src = jnp.einsum("nwhd,hd->nwh", z_nbr, p["a_src"])          # (n,w,h)
        e = jax.nn.leaky_relu(e_src + e_dst[:, None, :], 0.2)
        e = jnp.where(mask[..., None], e, -1e9)
        alpha = jax.nn.softmax(e, axis=1)
        alpha = jnp.where(mask[..., None], alpha, 0.0)
        agg = jnp.einsum("nwh,nwhd->nhd", alpha, z_nbr)
        agg = agg.reshape(*agg.shape[:-2], -1)                          # (n, h*dh)
        self_part = z_self.reshape(*z_self.shape[:-2], -1)
        return act((agg + self_part) @ p["w_out"] + p["b"])
    if cfg.model == "rgcn":
        out = h_self @ p["w_self"]
        et = etypes if etypes is not None else jnp.zeros(mask.shape, jnp.int32)
        for r in range(cfg.num_relations):
            m_r = mask & (et == r)
            agg_r = _masked_mean(h_nbr, m_r)
            out = out + agg_r @ p["w_rel"][r]
        return act(out + p["b"])
    raise ValueError(cfg.model)


def gnn_apply(
    params: dict,
    cfg: GNNConfig,
    plan_layers,            # sequence of layer blocks (Minibatch or Coop)
    H_input: jax.Array,     # embeddings for the deepest frontier
    provide: Callable[[int, jax.Array], jax.Array] = lambda l, H: H,
) -> jax.Array:
    """Forward pass over an L-layer plan; returns seed logits (cap_0, C).

    ``provide(l, H)`` converts owned embeddings into request-side
    embeddings for layer ``l`` (identity for Independent Minibatching,
    ``cooperative.redistribute`` for Cooperative).
    """
    H = H_input
    for l in reversed(range(cfg.num_layers)):
        blk = plan_layers[l]
        Ht = provide(l, H)
        H = layer_apply(
            params["layers"][l], cfg, l, Ht, blk.self_idx, blk.nbr_idx, blk.mask,
            blk.etypes,
        )
    return H


def gnn_apply_cooperative(
    params: dict,
    cfg: GNNConfig,
    ex,                     # cooperative.Executor
    plan_layers,            # CoopLayer blocks
    H_input: jax.Array,     # per-PE owned input embeddings
    tilde_caps,             # static S~ capacities per layer
) -> jax.Array:
    """Cooperative forward (Alg. 1): redistribute, then per-PE compute.

    The redistribution is a *global* exchange (all PEs participate);
    the bipartite layer compute is per-PE and goes through ``ex.pe`` so
    the same code runs under SimExecutor (vmap) and ShardExecutor
    (shard_map).
    """
    from repro.core.cooperative import redistribute

    H = H_input
    for l in reversed(range(cfg.num_layers)):
        blk = plan_layers[l]
        Ht = redistribute(ex, blk, H, tilde_caps[l])
        p_l = params["layers"][l]

        if blk.etypes is None:
            def apply_one(Ht, si, ni, mk, _p=p_l, _l=l):
                return layer_apply(_p, cfg, _l, Ht, si, ni, mk, None)

            H = ex.pe(apply_one, Ht, blk.self_idx, blk.nbr_idx, blk.mask)
        else:
            def apply_one_et(Ht, si, ni, mk, et, _p=p_l, _l=l):
                return layer_apply(_p, cfg, _l, Ht, si, ni, mk, et)

            H = ex.pe(
                apply_one_et, Ht, blk.self_idx, blk.nbr_idx, blk.mask, blk.etypes
            )
    return H
