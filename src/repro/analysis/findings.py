"""Finding model shared by every analysis pass.

A :class:`Finding` is one rule violation anchored to ``file:line`` (the
anchor is clickable in most terminals/editors).  Severities gate the CLI
exit code: by default only ``error`` findings fail a run, so advisory
``warning``/``info`` findings can accumulate without breaking CI.

Inline suppression: append ``# ra: ignore`` (all rules) or
``# ra: ignore[RA003]`` / ``# ra: ignore[RA001, RA003]`` (specific rule
ids) to the offending source line.  ``repro-analysis`` is accepted as a
long-form alias for ``ra``.
"""
from __future__ import annotations

import enum
import json
import re
from dataclasses import dataclass, field
from typing import Iterable, Optional


class Severity(enum.IntEnum):
    """Ordered so that gating is a plain comparison."""

    INFO = 10
    WARNING = 20
    ERROR = 30

    @classmethod
    def parse(cls, name: str) -> "Severity":
        try:
            return cls[name.upper()]
        except KeyError:
            raise ValueError(
                f"unknown severity {name!r}; expected one of "
                f"{[s.name.lower() for s in cls]}"
            ) from None


@dataclass(frozen=True)
class Finding:
    """One analyzer finding with a stable rule id and source anchor."""

    rule: str                 # e.g. "RA001"
    severity: Severity
    message: str
    file: str = "<none>"      # path as given on the command line
    line: int = 0             # 1-based; 0 = whole-file / non-source finding
    col: int = 0              # 0-based column offset (ast convention)
    extra: dict = field(default_factory=dict)  # rule-specific payload

    @property
    def anchor(self) -> str:
        if self.line:
            return f"{self.file}:{self.line}"
        return self.file

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity.name.lower(),
            "file": self.file,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "extra": self.extra,
        }

    def render(self) -> str:
        return (
            f"{self.anchor}: {self.severity.name.lower()}: "
            f"[{self.rule}] {self.message}"
        )


# --- inline suppression ----------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*(?:ra|repro-analysis)\s*:\s*ignore"
    r"(?:\[(?P<rules>[A-Za-z0-9_,\s]+)\])?"
)


def suppressed_rules(source_line: str) -> Optional[frozenset]:
    """Rule ids suppressed on ``source_line``.

    Returns ``None`` when the line carries no suppression comment, an
    empty frozenset for a bare ``# ra: ignore`` (suppress everything),
    or the frozenset of named rule ids.
    """
    m = _SUPPRESS_RE.search(source_line)
    if m is None:
        return None
    rules = m.group("rules")
    if rules is None:
        return frozenset()
    return frozenset(r.strip().upper() for r in rules.split(",") if r.strip())


def is_suppressed(finding: Finding, source_lines: list) -> bool:
    """True when the finding's source line carries a matching suppression."""
    if not finding.line or finding.line > len(source_lines):
        return False
    rules = suppressed_rules(source_lines[finding.line - 1])
    if rules is None:
        return False
    return not rules or finding.rule.upper() in rules


# --- report ----------------------------------------------------------------

@dataclass
class Report:
    """Aggregate result of an analysis run (all passes)."""

    findings: list = field(default_factory=list)
    passes_run: list = field(default_factory=list)
    wall_s: float = 0.0
    files_scanned: int = 0

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def count_at_least(self, severity: Severity) -> int:
        return sum(1 for f in self.findings if f.severity >= severity)

    def rule_counts(self) -> dict:
        counts: dict = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return dict(sorted(counts.items()))

    def exit_code(self, fail_on: Severity = Severity.ERROR) -> int:
        return 1 if self.count_at_least(fail_on) else 0

    def to_dict(self) -> dict:
        return {
            "passes": self.passes_run,
            "files_scanned": self.files_scanned,
            "wall_s": round(self.wall_s, 3),
            "rule_counts": self.rule_counts(),
            "counts": {
                s.name.lower(): sum(
                    1 for f in self.findings if f.severity == s
                )
                for s in Severity
            },
            "findings": [f.to_dict() for f in self.findings],
        }

    def render_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=False)

    def render_text(self) -> str:
        lines = []
        order = sorted(
            self.findings, key=lambda f: (-int(f.severity), f.file, f.line)
        )
        for f in order:
            lines.append(f.render())
        n_err = self.count_at_least(Severity.ERROR)
        n_warn = sum(1 for f in self.findings if f.severity == Severity.WARNING)
        n_info = sum(1 for f in self.findings if f.severity == Severity.INFO)
        lines.append(
            f"repro.analysis: {self.files_scanned} file(s), "
            f"passes={','.join(self.passes_run) or 'none'}: "
            f"{n_err} error(s), {n_warn} warning(s), {n_info} info "
            f"in {self.wall_s:.2f}s"
        )
        return "\n".join(lines)
