"""Repo-specific lint rules (RA0xx).

Rule catalog
------------
RA001 host-sync-in-stream   ``.item()`` / ``jax.device_get`` /
                            ``block_until_ready`` inside a hot path.
RA002 numpy-in-hot-path     host ``numpy`` call inside a jit-traced or
                            streaming hot path.
RA003 rng-key-reuse         a ``jax.random`` key consumed twice without
                            being split/reassigned in between.
RA004 traced-python-branch  Python ``if``/``while`` on a traced (jnp)
                            expression inside a jit function.
RA005 bare-assert-kernel    ``assert`` precondition in a Pallas kernel
                            module — use KernelContractError instead.

Every rule reports with a stable id so findings can be suppressed
inline (``# ra: ignore[RA003]``) and counted across runs.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.findings import Finding, Severity
from repro.analysis.lint import FileContext, LintRule

#: jax.random functions that CONSUME the key passed to them — after one
#: of these, reusing the same key correlates what must be independent.
_KEY_CONSUMERS = frozenset({
    "split", "fold_in", "normal", "uniform", "randint", "bernoulli",
    "categorical", "choice", "permutation", "shuffle", "gumbel",
    "truncated_normal", "bits", "exponential", "laplace", "poisson",
    "dirichlet", "beta", "gamma", "cauchy", "rademacher", "ball",
    "orthogonal", "t", "loggamma", "multivariate_normal",
})

#: functions whose result *is* a fresh key (assignment targets become keys)
_KEY_PRODUCERS = frozenset({"PRNGKey", "key", "split", "fold_in", "clone"})

_HOST_SYNC_ATTRS = frozenset({"block_until_ready"})
_HOST_SYNC_JAX = frozenset({"jax.device_get", "jax.block_until_ready"})


class HostSyncInHotPath(LintRule):
    rule_id = "RA001"
    severity = Severity.ERROR
    title = "host-sync-in-stream"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not ctx.is_hot(node):
                continue
            q = ctx.qualify(node.func)
            if q in _HOST_SYNC_JAX:
                yield self.finding(
                    ctx, node,
                    f"`{q}` forces a device->host sync inside a hot path; "
                    "it stalls the stream/step pipeline — hoist it out of "
                    "the hot path or drop it",
                    call=q,
                )
            elif isinstance(node.func, ast.Attribute):
                if node.func.attr == "item" and not node.args:
                    yield self.finding(
                        ctx, node,
                        "`.item()` blocks on device completion inside a hot "
                        "path; keep values on device (or sync once per "
                        "logging interval outside the hot loop)",
                        call=".item()",
                    )
                elif node.func.attr in _HOST_SYNC_ATTRS:
                    yield self.finding(
                        ctx, node,
                        "`.block_until_ready()` inside a hot path defeats "
                        "async dispatch; only benchmarks should sync",
                        call=".block_until_ready()",
                    )


class NumpyInHotPath(LintRule):
    rule_id = "RA002"
    severity = Severity.ERROR
    title = "numpy-in-hot-path"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not ctx.is_hot(node):
                continue
            q = ctx.qualify(node.func)
            if q and (q == "numpy" or q.startswith("numpy.")):
                yield self.finding(
                    ctx, node,
                    f"host `{q}` call inside a jit/stream hot path: under "
                    "trace it either bakes a constant or falls back to "
                    "host; use the jax.numpy equivalent",
                    call=q,
                )


class RngKeyReuse(LintRule):
    rule_id = "RA003"
    severity = Severity.ERROR
    title = "rng-key-reuse"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.functions:
            yield from self._check_fn(ctx, fn)

    # -- helpers -----------------------------------------------------------

    def _is_random_call(self, ctx: FileContext, call: ast.Call) -> Optional[str]:
        """Returns the jax.random function name, or None."""
        q = ctx.qualify(call.func)
        if q and q.startswith("jax.random."):
            return q.rsplit(".", 1)[1]
        return None

    def _check_fn(self, ctx: FileContext, fn) -> Iterator[Finding]:
        # Ordered statement scan over this function's own body (nested
        # defs are analyzed separately).  Straight-line approximation:
        # exclusive if/else arms are treated as sequential, which only
        # over-reports for code consuming the same key on both arms —
        # rare, and suppressible inline.
        keys: dict = {}        # name -> "live" | "consumed"
        consumed_sub: set = set()  # (name, const_index) sub-keys consumed
        findings = []

        def key_token(expr):
            """Bare `k` -> "k"; `ks[0]` -> ("ks", 0); else None."""
            if isinstance(expr, ast.Name):
                return expr.id
            if (
                isinstance(expr, ast.Subscript)
                and isinstance(expr.value, ast.Name)
                and isinstance(expr.slice, ast.Constant)
            ):
                return (expr.value.id, expr.slice.value)
            return None

        def handle_call(call: ast.Call):
            name = self._is_random_call(ctx, call)
            if name is None or name not in _KEY_CONSUMERS:
                return
            exprs = list(call.args) + [kw.value for kw in call.keywords]
            for expr in exprs:
                tok = key_token(expr)
                if tok is None:
                    continue
                if isinstance(tok, tuple):  # sub-key like ks[0]
                    if tok[0] not in keys:
                        continue
                    if tok in consumed_sub or keys.get(tok[0]) == "consumed":
                        findings.append(self.finding(
                            ctx, call,
                            f"PRNG sub-key `{tok[0]}[{tok[1]}]` is reused "
                            "after being consumed; split again for a "
                            "fresh key",
                            key=f"{tok[0]}[{tok[1]}]", consumer=name,
                        ))
                    else:
                        consumed_sub.add(tok)
                else:
                    if keys.get(tok) == "consumed":
                        findings.append(self.finding(
                            ctx, call,
                            f"PRNG key `{tok}` is reused after being "
                            "consumed; split it first (every jax.random "
                            "consumption must see a fresh key)",
                            key=tok, consumer=name,
                        ))
                    elif tok in keys:
                        keys[tok] = "consumed"

        def mark_targets(target, producing: bool):
            if isinstance(target, ast.Name):
                if producing:
                    keys[target.id] = "live"
                    consumed_sub.difference_update(
                        t for t in consumed_sub if t[0] == target.id
                    )
                else:
                    keys.pop(target.id, None)
            elif isinstance(target, (ast.Tuple, ast.List)):
                for elt in target.elts:
                    inner = elt.value if isinstance(elt, ast.Starred) else elt
                    mark_targets(inner, producing)

        def calls_in(expr):
            for sub in ast.walk(expr):
                if isinstance(sub, ast.Call):
                    yield sub

        def process_block(stmts):
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue  # separate scope
                if isinstance(stmt, ast.Assign):
                    for c in calls_in(stmt.value):
                        handle_call(c)
                    producing = (
                        isinstance(stmt.value, ast.Call)
                        and (self._is_random_call(ctx, stmt.value) or "")
                        in _KEY_PRODUCERS
                    )
                    for tgt in stmt.targets:
                        mark_targets(tgt, producing)
                    continue
                if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                    if stmt.value is not None:
                        for c in calls_in(stmt.value):
                            handle_call(c)
                    mark_targets(stmt.target, False)
                    continue
                # generic statement: consume calls in its expressions,
                # then recurse into nested blocks in source order
                for field_name in ("test", "iter", "value", "exc", "items"):
                    sub = getattr(stmt, field_name, None)
                    if sub is None:
                        continue
                    for expr in sub if isinstance(sub, list) else [sub]:
                        node = getattr(expr, "context_expr", expr)
                        for c in calls_in(node):
                            handle_call(c)
                for block_name in ("body", "orelse", "finalbody"):
                    block = getattr(stmt, block_name, None)
                    if isinstance(block, list):
                        process_block(
                            [s for s in block if isinstance(s, ast.stmt)]
                        )
                for handler in getattr(stmt, "handlers", []) or []:
                    process_block(handler.body)

        process_block(fn.body)
        yield from findings


class TracedPythonBranch(LintRule):
    rule_id = "RA004"
    severity = Severity.ERROR
    title = "traced-python-branch"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.If, ast.While)) or not ctx.is_hot(node):
                continue
            culprit = self._traced_expr(ctx, node.test)
            if culprit:
                kind = "if" if isinstance(node, ast.If) else "while"
                yield self.finding(
                    ctx, node,
                    f"Python `{kind}` on traced expression `{culprit}` "
                    "inside a jit scope: branching on a traced value "
                    "raises TracerBoolConversionError or silently "
                    "specializes; use jnp.where / lax.cond",
                    expr=culprit,
                )

    def _traced_expr(self, ctx: FileContext, test: ast.AST) -> Optional[str]:
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call):
                q = ctx.qualify(sub.func)
                if q and (
                    q.startswith("jax.numpy.") or q.startswith("jax.lax.")
                ):
                    return q
        return None


class BareAssertInKernel(LintRule):
    rule_id = "RA005"
    severity = Severity.ERROR
    title = "bare-assert-kernel"

    def _is_kernel_module(self, ctx: FileContext) -> bool:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                q = ctx.qualify(node.func)
                if q and q.endswith("pallas.pallas_call"):
                    return True
        return False

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not self._is_kernel_module(ctx):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                yield self.finding(
                    ctx, node,
                    "bare `assert` as a kernel precondition: asserts "
                    "vanish under `python -O` and carry no shapes; raise "
                    "KernelContractError (repro.kernels.errors) with the "
                    "offending values instead",
                )


def default_rules() -> list:
    return [
        HostSyncInHotPath(),
        NumpyInHotPath(),
        RngKeyReuse(),
        TracedPythonBranch(),
        BareAssertInKernel(),
    ]
