"""``python -m repro.analysis`` — run the static invariant checker.

Examples::

    python -m repro.analysis src/                 # all passes, text output
    python -m repro.analysis src/ --format json   # machine-readable
    python -m repro.analysis tests/fixtures/analysis/bad_key_reuse.py
    python -m repro.analysis src/ --passes lint,contracts --fail-on warning

Exit code is 1 when any finding at or above ``--fail-on`` severity
(default ``error``) survives, else 0.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Iterable, Optional

from repro.analysis.findings import Report, Severity

PASSES = ("lint", "contracts", "trace")


def _repo_package_dir() -> Optional[str]:
    try:
        import repro

        f = getattr(repro, "__file__", None)
        if f:  # regular package
            return os.path.dirname(os.path.abspath(f))
        paths = list(getattr(repro, "__path__", []))  # namespace package
        return os.path.abspath(paths[0]) if paths else None
    except Exception:
        return None


def _covers_repo(paths: Iterable[str]) -> bool:
    pkg = _repo_package_dir()
    if pkg is None:
        return False
    for p in paths:
        a = os.path.abspath(p)
        if pkg == a or pkg.startswith(a.rstrip(os.sep) + os.sep) \
                or a.startswith(pkg.rstrip(os.sep) + os.sep):
            return True
    return False


def run_analysis(
    paths: Iterable[str],
    passes: Iterable[str] = PASSES,
    vmem_budget: int = None,
) -> Report:
    """Programmatic entry point; returns a :class:`Report`."""
    from repro.analysis.contracts import DEFAULT_VMEM_BUDGET, run_contracts
    from repro.analysis.lint import run_lint
    from repro.analysis.trace import run_trace

    paths = [str(p) for p in paths]
    passes = list(passes)
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    report = Report()
    t0 = time.perf_counter()

    if "lint" in passes:
        findings, n_files = run_lint(paths)
        report.extend(findings)
        report.files_scanned += n_files
        report.passes_run.append("lint")
    if "contracts" in passes:
        report.extend(run_contracts(paths, vmem_budget=budget))
        report.passes_run.append("contracts")
    if "trace" in passes:
        # the trace pass exercises live repo entry points, so it only
        # fires when the analyzed paths cover the repro package itself
        if _covers_repo(paths):
            report.extend(run_trace())
            report.passes_run.append("trace")

    report.wall_s = time.perf_counter() - t0
    return report


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker: AST lint (RA0xx), Pallas "
                    "kernel contracts (RA1xx), trace hygiene (RA2xx).",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to analyze (default: src)",
    )
    ap.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)",
    )
    ap.add_argument(
        "--passes", default=",".join(PASSES),
        help=f"comma-separated subset of {{{','.join(PASSES)}}} "
             "(default: all)",
    )
    ap.add_argument(
        "--fail-on", default="error", metavar="SEVERITY",
        help="minimum severity that fails the run: info|warning|error "
             "(default: error)",
    )
    ap.add_argument(
        "--vmem-budget", type=int, default=None, metavar="BYTES",
        help="per-step VMEM budget for the kernel contract checker "
             "(default: 16 MiB)",
    )
    ap.add_argument(
        "--output", default=None, metavar="FILE",
        help="also write the report (in the chosen format) to FILE",
    )
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        ap.error(f"unknown pass(es): {', '.join(unknown)}")
    for p in args.paths:
        if not os.path.exists(p):
            ap.error(f"path does not exist: {p}")

    report = run_analysis(
        args.paths, passes=passes, vmem_budget=args.vmem_budget
    )
    rendered = (
        report.render_json() if args.format == "json" else report.render_text()
    )
    print(rendered)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(rendered + "\n")
    return report.exit_code(Severity.parse(args.fail_on))


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
