"""Kernel contract checker: verify ``pl.pallas_call`` sites statically.

Strategy: monkeypatch ``jax.experimental.pallas.pallas_call`` with a
recording stub and invoke each registered kernel wrapper under
``jax.disable_jit()`` on representative (production block size) shapes.
The kernel body never runs and nothing compiles or touches a device —
the stub receives the *actual* grid / BlockSpecs / operands the wrapper
constructs and checks, per call site:

* RA101 block divisibility — every ``block_shape[k]`` divides the
  operand's ``shape[k]``;
* RA102 index-map arity — each BlockSpec ``index_map`` takes exactly
  ``len(grid)`` arguments;
* RA103 index-map rank — the index map returns one coordinate per
  block dimension;
* RA104 grid coverage — enumerating the grid, the output index map
  hits every output tile;
* RA105 init coverage — if an output tile is revisited across grid
  steps (its index map ignores a grid axis) the kernel body must guard
  a first-visit initialization with ``pl.when(... == 0)``;
* RA106 VMEM budget — 2x double-buffered input tiles + output tile
  must fit the configured budget (default 16 MiB);
* RA107 typed preconditions — calling the wrapper with contract-
  violating shapes must raise :class:`KernelContractError`, not a bare
  ``AssertionError`` or nothing.

Fixture / third-party modules are supported via a module-level
``ANALYSIS_TARGETS = [{"fn": ..., "args": ..., "bad_args": [...]}]``
declaration — the checker picks those up for any ``.py`` file passed on
the command line.
"""
from __future__ import annotations

import ast
import functools
import importlib
import importlib.util
import inspect
import itertools
import math
import os
import textwrap
import traceback
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional

from repro.analysis.findings import Finding, Severity

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024  # v5e per-core VMEM
_MAX_GRID_ENUM = 65536


@dataclass
class KernelTarget:
    """One kernel wrapper to verify."""

    name: str
    module: str                      # import path ("repro.kernels...") or file
    fn: str
    make_args: Callable              # () -> (args tuple, kwargs dict)
    bad_args: list = field(default_factory=list)  # callables, same shape


def repo_targets() -> List[KernelTarget]:
    """The shipped Pallas kernels, at production block sizes."""
    import jax.numpy as jnp

    def gather_args():
        table = jnp.zeros((4096, 128), jnp.float32)
        ids = jnp.zeros((512,), jnp.int32)
        return (table, ids), dict(block_n=512, block_d=128, page=2048)

    def gather_bad():
        table = jnp.zeros((4000, 128), jnp.float32)  # 4000 % 2048 != 0
        ids = jnp.zeros((512,), jnp.int32)
        return (table, ids), dict(block_n=512, block_d=128, page=2048)

    def spmm_args():
        src = jnp.zeros((8192, 128), jnp.float32)
        idx = jnp.zeros((128, 16), jnp.int32)
        mask = jnp.ones((128, 16), bool)
        return (src, idx, mask), dict(block_n=128, block_d=128)

    def spmm_bad():
        src = jnp.zeros((8192, 100), jnp.float32)  # 100 % 128 != 0
        idx = jnp.zeros((128, 16), jnp.int32)
        mask = jnp.ones((128, 16), bool)
        return (src, idx, mask), dict(block_n=128, block_d=128)

    def seg_args():
        e = jnp.zeros((512, 16), jnp.float32)
        mask = jnp.ones((512, 16), bool)
        return (e, mask), dict(block_n=256)

    def seg_bad():
        e = jnp.zeros((500, 16), jnp.float32)  # 500 % 256 != 0
        mask = jnp.ones((500, 16), bool)
        return (e, mask), dict(block_n=256)

    def probe_args():
        tags = jnp.zeros((2048, 8), jnp.int32)
        sets = jnp.zeros((512,), jnp.int32)
        ids = jnp.zeros((512,), jnp.int32)
        return (tags, sets, ids), dict(block_n=512, page=1024)

    def probe_bad():
        tags = jnp.zeros((2000, 8), jnp.int32)  # 2000 % 1024 != 0
        sets = jnp.zeros((512,), jnp.int32)
        ids = jnp.zeros((512,), jnp.int32)
        return (tags, sets, ids), dict(block_n=512, page=1024)

    def uniq_args():
        ids = jnp.zeros((1024,), jnp.int32)
        return (ids, 512), dict(block_m=256)

    def uniq_bad():
        ids = jnp.zeros((1000,), jnp.int32)  # 1000 % 256 != 0
        return (ids, 512), dict(block_m=256)

    def uniq_bad_cap():
        ids = jnp.zeros((1024,), jnp.int32)
        return (ids, 0), dict(block_m=256)  # cap must be >= 1

    def frontier_args():
        indptr = jnp.zeros((4097,), jnp.int32)
        indices = jnp.zeros((8192,), jnp.int32)
        seeds = jnp.zeros((512,), jnp.int32)
        return (indptr, indices, seeds), dict(
            max_degree=16, block_n=256, page=2048,
        )

    def frontier_bad():
        indptr = jnp.zeros((4097,), jnp.int32)
        indices = jnp.zeros((8000,), jnp.int32)  # 8000 % 2048 != 0
        seeds = jnp.zeros((512,), jnp.int32)
        return (indptr, indices, seeds), dict(
            max_degree=16, block_n=256, page=2048,
        )

    def expand_args():
        indptr = jnp.zeros((257,), jnp.int32)
        return (indptr, 4096), dict(block_e=512)

    def expand_bad():
        indptr = jnp.zeros((257,), jnp.int32)
        return (indptr, 4000), dict(block_e=512)  # 4000 % 512 != 0

    return [
        KernelTarget(
            "gather", "repro.kernels.gather.kernel", "paged_gather_pallas",
            gather_args, [gather_bad],
        ),
        KernelTarget(
            "unique_compact", "repro.kernels.unique_compact.kernel",
            "unique_compact_pallas", uniq_args, [uniq_bad, uniq_bad_cap],
        ),
        KernelTarget(
            "frontier_gather", "repro.kernels.frontier_gather.kernel",
            "frontier_gather_pallas", frontier_args, [frontier_bad],
        ),
        KernelTarget(
            "expand_indptr", "repro.kernels.expand_indptr.kernel",
            "expand_indptr_pallas", expand_args, [expand_bad],
        ),
        KernelTarget(
            "spmm", "repro.kernels.spmm.kernel", "spmm_pallas",
            spmm_args, [spmm_bad],
        ),
        KernelTarget(
            "seg_softmax", "repro.kernels.seg_softmax.kernel",
            "seg_softmax_pallas", seg_args, [seg_bad],
        ),
        KernelTarget(
            "tag_probe", "repro.store.kernel", "tag_probe_pallas",
            probe_args, [probe_bad],
        ),
    ]


# --- pallas_call interception ----------------------------------------------

@dataclass
class _CallSite:
    kernel_fn: Callable
    grid: tuple
    in_specs: list
    out_specs: object
    out_shape: object
    operands: tuple = ()
    file: str = "<unknown>"
    line: int = 0


class _Recorder:
    """Stands in for ``pl.pallas_call``; records sites, returns zeros."""

    def __init__(self):
        self.sites: List[_CallSite] = []

    def __call__(self, kernel, *, grid=None, in_specs=None, out_specs=None,
                 out_shape=None, **kwargs):
        # anchor the finding at the pl.pallas_call( source line
        stack = traceback.extract_stack()
        frame = stack[-2] if len(stack) >= 2 else None
        site = _CallSite(
            kernel_fn=kernel,
            grid=(grid,) if isinstance(grid, int) else tuple(grid or ()),
            in_specs=list(in_specs or []),
            out_specs=out_specs,
            out_shape=out_shape,
            file=frame.filename if frame else "<unknown>",
            line=frame.lineno if frame else 0,
        )
        self.sites.append(site)

        def fake(*operands):
            import jax.numpy as jnp

            site.operands = operands
            structs = out_shape
            single = not isinstance(structs, (tuple, list))
            outs = [
                jnp.zeros(s.shape, s.dtype)
                for s in ([structs] if single else structs)
            ]
            return outs[0] if single else tuple(outs)

        return fake


def _block_shape(spec) -> tuple:
    bs = getattr(spec, "block_shape", None)
    return tuple(bs) if bs is not None else ()


def _index_map(spec):
    return getattr(spec, "index_map", None)


def _normalize_coords(res) -> tuple:
    if isinstance(res, tuple):
        return res
    if isinstance(res, list):
        return tuple(res)
    return (res,)


def _kernel_body_has_init(kernel_fn) -> Optional[bool]:
    """True if the kernel body guards a first-visit init via pl.when(==0).

    None when the source is unavailable (builtins, exec'd code).
    """
    fn = kernel_fn
    while isinstance(fn, functools.partial):
        fn = fn.func
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        return None

    def is_when_eq0(call: ast.Call) -> bool:
        f = call.func
        name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
        if name != "when":
            return False
        for arg in call.args:
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Compare) and any(
                    isinstance(op, ast.Eq) for op in sub.ops
                ):
                    consts = [
                        c.value
                        for c in ast.walk(sub)
                        if isinstance(c, ast.Constant)
                    ]
                    if 0 in consts:
                        return True
        return False

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and is_when_eq0(node):
            return True
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and is_when_eq0(dec):
                    return True
    return False


def _kernel_body_accumulates(kernel_fn) -> bool:
    fn = kernel_fn
    while isinstance(fn, functools.partial):
        fn = fn.func
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        return False
    return any(
        isinstance(n, ast.AugAssign) and isinstance(n.target, ast.Subscript)
        for n in ast.walk(tree)
    )


def _check_site(
    site: _CallSite, target_name: str, vmem_budget: int
) -> List[Finding]:
    out: List[Finding] = []

    def finding(rule, severity, message, **extra):
        out.append(Finding(
            rule=rule, severity=severity, message=message,
            file=site.file, line=site.line,
            extra=dict(kernel=target_name, **extra),
        ))

    grid = site.grid
    n_grid = len(grid)
    out_specs = site.out_specs
    out_shapes = site.out_shape
    single_out = not isinstance(out_specs, (tuple, list))
    out_pairs = list(zip(
        [out_specs] if single_out else list(out_specs),
        [out_shapes] if not isinstance(out_shapes, (tuple, list))
        else list(out_shapes),
    ))

    if len(site.in_specs) != len(site.operands):
        finding(
            "RA102", Severity.ERROR,
            f"{len(site.in_specs)} in_specs for {len(site.operands)} "
            "operands",
        )
        return out

    # per-spec structural checks ------------------------------------------
    all_pairs = [
        (spec, tuple(op.shape), getattr(op.dtype, "itemsize", 4), "in", i)
        for i, (spec, op) in enumerate(zip(site.in_specs, site.operands))
    ] + [
        (spec, tuple(struct.shape), struct.dtype.itemsize, "out", i)
        for i, (spec, struct) in enumerate(out_pairs)
    ]

    vmem_in = 0
    vmem_out = 0
    structurally_ok = True
    for spec, shape, itemsize, role, idx in all_pairs:
        label = f"{role}_specs[{idx}]"
        block = _block_shape(spec)
        imap = _index_map(spec)
        if imap is not None:
            try:
                arity = len(inspect.signature(imap).parameters)
            except (ValueError, TypeError):
                arity = n_grid
            if arity != n_grid:
                structurally_ok = False
                finding(
                    "RA102", Severity.ERROR,
                    f"{label}: index_map takes {arity} args but the grid "
                    f"has {n_grid} dimensions",
                    arity=arity, grid=list(grid),
                )
                continue
            coords = _normalize_coords(imap(*([0] * n_grid)))
            if len(coords) != len(block):
                structurally_ok = False
                finding(
                    "RA103", Severity.ERROR,
                    f"{label}: index_map returns {len(coords)} "
                    f"coordinate(s) for a rank-{len(block)} block "
                    f"{block}",
                    coords=len(coords), block=list(block),
                )
                continue
        if len(block) != len(shape):
            structurally_ok = False
            finding(
                "RA103", Severity.ERROR,
                f"{label}: block {block} has rank {len(block)} but the "
                f"operand has rank {len(shape)} (shape {shape})",
                block=list(block), shape=list(shape),
            )
            continue
        for k, (dim, b) in enumerate(zip(shape, block)):
            if b is None:
                continue
            if b <= 0 or dim % b != 0:
                finding(
                    "RA101", Severity.ERROR,
                    f"{label}: operand dim {k} of size {dim} is not "
                    f"divisible by block size {b} — the trailing "
                    "partial tile reads out of bounds (pad the operand "
                    "or fix the BlockSpec)",
                    dim=k, size=dim, block=b,
                )
        nbytes = math.prod(b for b in block if b) * itemsize
        if role == "in":
            vmem_in += nbytes
        else:
            vmem_out += nbytes

    # grid coverage + init coverage ---------------------------------------
    if structurally_ok and grid and math.prod(grid) <= _MAX_GRID_ENUM:
        for out_idx, (spec, struct) in enumerate(out_pairs):
            block = _block_shape(spec)
            imap = _index_map(spec)
            if imap is None or len(block) != len(tuple(struct.shape)):
                continue
            if any(b in (None, 0) or dim % b for dim, b in
                   zip(struct.shape, block)):
                continue
            tiles: dict = {}
            for g in itertools.product(*(range(s) for s in grid)):
                c = _normalize_coords(imap(*g))
                tiles[c] = tiles.get(c, 0) + 1
            expected = set(itertools.product(
                *(range(dim // b) for dim, b in zip(struct.shape, block))
            ))
            missing = expected - set(tiles)
            if missing:
                finding(
                    "RA104", Severity.ERROR,
                    f"out_specs[{out_idx}]: grid {tuple(grid)} never "
                    f"writes {len(missing)} of {len(expected)} output "
                    f"tile(s) (first missing: {sorted(missing)[0]}) — "
                    "those tiles are returned uninitialized",
                    missing=len(missing), expected=len(expected),
                )
            revisits = max(tiles.values(), default=0) > 1
            if revisits:
                has_init = _kernel_body_has_init(site.kernel_fn)
                accumulates = _kernel_body_accumulates(site.kernel_fn)
                if has_init is False and accumulates:
                    finding(
                        "RA105", Severity.ERROR,
                        f"out_specs[{out_idx}]: output tile is revisited "
                        "across grid steps and the kernel accumulates "
                        "(`ref[...] += ...`) without a `pl.when(p == 0)` "
                        "init branch — the first visit reads garbage "
                        "VMEM",
                    )
                elif has_init is False:
                    finding(
                        "RA105", Severity.WARNING,
                        f"out_specs[{out_idx}]: output tile is revisited "
                        "across grid steps but the kernel neither "
                        "accumulates nor initializes on first visit — "
                        "later visits silently overwrite earlier ones",
                    )

    # VMEM budget ----------------------------------------------------------
    est = 2 * vmem_in + vmem_out  # Pallas double-buffers inputs
    if est > vmem_budget:
        finding(
            "RA106", Severity.ERROR,
            f"estimated per-step VMEM footprint {est / 2**20:.2f} MiB "
            f"(2x double-buffered inputs {vmem_in / 2**20:.2f} + output "
            f"{vmem_out / 2**20:.2f}) exceeds the "
            f"{vmem_budget / 2**20:.0f} MiB budget — shrink block sizes",
            estimated_bytes=est, budget_bytes=vmem_budget,
        )
    elif not any(f.severity >= Severity.ERROR for f in out):
        finding(
            "RA100", Severity.INFO,
            f"verified: grid={tuple(grid)}, "
            f"{len(site.in_specs)} in_specs, est VMEM "
            f"{est / 2**20:.2f} MiB / {vmem_budget / 2**20:.0f} MiB",
            estimated_bytes=est, grid=list(grid),
        )
    return out


# --- target execution ------------------------------------------------------

def _load_module(target: KernelTarget):
    if target.module.endswith(".py") or os.sep in target.module:
        name = "_ra_fixture_" + os.path.basename(target.module)[:-3]
        spec = importlib.util.spec_from_file_location(name, target.module)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod
    return importlib.import_module(target.module)


def check_target(
    target: KernelTarget, vmem_budget: int = DEFAULT_VMEM_BUDGET
) -> List[Finding]:
    import jax
    from jax.experimental import pallas

    findings: List[Finding] = []
    try:
        mod = _load_module(target)
        fn = getattr(mod, target.fn)
    except Exception as e:
        return [Finding(
            rule="RA199", severity=Severity.ERROR,
            message=f"could not load kernel target "
                    f"{target.module}:{target.fn}: {e!r}",
            file=target.module,
        )]
    mod_file = getattr(mod, "__file__", target.module) or target.module

    recorder = _Recorder()
    real = pallas.pallas_call
    pallas.pallas_call = recorder
    try:
        with jax.disable_jit():
            args, kwargs = target.make_args()
            try:
                fn(*args, **kwargs)
            except Exception as e:
                findings.append(Finding(
                    rule="RA199", severity=Severity.ERROR,
                    message=f"kernel wrapper `{target.fn}` raised on its "
                            f"reference shapes: {e!r}",
                    file=mod_file,
                ))
            # typed-precondition probes
            for i, bad in enumerate(target.bad_args):
                bargs, bkwargs = bad()
                try:
                    fn(*bargs, **bkwargs)
                except Exception as e:
                    if type(e).__name__ != "KernelContractError":
                        findings.append(Finding(
                            rule="RA107", severity=Severity.ERROR,
                            message=f"`{target.fn}` bad-shape probe #{i} "
                                    f"raised {type(e).__name__} instead of "
                                    "KernelContractError — preconditions "
                                    "must be typed, not bare asserts",
                            file=mod_file,
                            extra=dict(raised=type(e).__name__),
                        ))
                else:
                    findings.append(Finding(
                        rule="RA107", severity=Severity.ERROR,
                        message=f"`{target.fn}` bad-shape probe #{i} was "
                                "accepted silently — add a "
                                "KernelContractError precondition",
                        file=mod_file,
                    ))
    finally:
        pallas.pallas_call = real

    if not recorder.sites and not any(f.rule == "RA199" for f in findings):
        findings.append(Finding(
            rule="RA199", severity=Severity.ERROR,
            message=f"`{target.fn}` never reached pl.pallas_call on its "
                    "reference shapes — nothing to verify",
            file=mod_file,
        ))
    for site in recorder.sites:
        findings.extend(_check_site(site, target.name, vmem_budget))
    return findings


# --- discovery over CLI paths ----------------------------------------------

def _path_covers(path: str, file: str) -> bool:
    p = os.path.abspath(path)
    f = os.path.abspath(file)
    return f == p or f.startswith(p.rstrip(os.sep) + os.sep)


def fixture_targets(py_file: str) -> List[KernelTarget]:
    """Targets declared via ``ANALYSIS_TARGETS`` in an arbitrary file."""
    try:
        with open(py_file, "r", encoding="utf-8") as fh:
            if "ANALYSIS_TARGETS" not in fh.read():
                return []
    except OSError:
        return []
    name = "_ra_scan_" + os.path.basename(py_file)[:-3]
    try:
        spec = importlib.util.spec_from_file_location(name, py_file)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    except Exception:
        return []
    targets = []
    for i, decl in enumerate(getattr(mod, "ANALYSIS_TARGETS", []) or []):
        targets.append(KernelTarget(
            name=f"{os.path.basename(py_file)[:-3]}:{decl['fn']}",
            module=py_file,
            fn=decl["fn"],
            make_args=decl["args"],
            bad_args=list(decl.get("bad_args", [])),
        ))
    return targets


def run_contracts(
    paths: Iterable[str], vmem_budget: int = DEFAULT_VMEM_BUDGET
) -> List[Finding]:
    from repro.analysis.lint import iter_python_files

    findings: List[Finding] = []
    paths = list(paths)

    # repo kernels, when a path covers the kernels package
    try:
        import repro.kernels as _k

        kdir = os.path.dirname(os.path.abspath(_k.__file__))
    except Exception:
        kdir = None
    if kdir and any(
        _path_covers(p, kdir) or _path_covers(kdir, p) for p in paths
    ):
        for target in repo_targets():
            findings.extend(check_target(target, vmem_budget))

    # fixture-declared targets anywhere under the given paths
    for py in iter_python_files(paths):
        if kdir and _path_covers(kdir, py):
            continue  # repo kernels already covered above
        for target in fixture_targets(py):
            findings.extend(check_target(target, vmem_budget))
    return findings
