"""Trace-hygiene pass: recompilation + implicit-transfer detection.

Wraps the repo's ``jax.jit`` entry points (the three Pallas kernel ops
in interpret mode, CSR neighbor lookup, and the engine's plan-build
step) in a counting harness, runs each on tiny synthetic shapes with
call variants that MUST share one compilation (fresh same-shape inputs,
successive schedule steps), and reports:

* RA201 silent-recompilation — a variant retraced (weak-type
  promotion, shape drift, python-scalar step instead of ``jnp.int32``);
* RA202 implicit-host-transfer — executing the compiled step moved
  data host<->device implicitly (detected via ``jax.transfer_guard``);
* RA203 unhashable-static-arg — jit rejected a static argument;
* RA299 harness-failure — the entry point could not be exercised.

The engine entry doubles as a regression gate for the engine's core
trace contract: ``rng_state(step)`` is a *dynamic* function of the step,
so one compiled plan-build must serve every step of a κ schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List

from repro.analysis.findings import Finding, Severity


@dataclass
class TraceEntry:
    """One jit entry point plus call variants that must share a trace."""

    name: str
    anchor: str                     # file:line-ish anchor for findings
    build: Callable                 # () -> (fn, static_argnames, [() -> (args, kwargs)])


def _kernel_entries() -> List[TraceEntry]:
    import jax.numpy as jnp

    def gather():
        from repro.kernels.gather.kernel import paged_gather_pallas

        def fn(table, ids):
            return paged_gather_pallas(
                table, ids, block_n=8, block_d=128, page=8, interpret=True
            )

        t0 = jnp.zeros((16, 128), jnp.float32)
        t1 = jnp.ones((16, 128), jnp.float32)
        i0 = jnp.zeros((8,), jnp.int32)
        i1 = jnp.arange(8, dtype=jnp.int32)
        return fn, (), [
            lambda: ((t0, i0), {}),
            lambda: ((t1, i1), {}),
        ]

    def spmm():
        from repro.kernels.spmm.kernel import spmm_pallas

        def fn(src, idx, mask):
            return spmm_pallas(
                src, idx, mask, mean=True, block_n=8, block_d=128,
                interpret=True,
            )

        s0 = jnp.zeros((16, 128), jnp.float32)
        s1 = jnp.ones((16, 128), jnp.float32)
        ix = jnp.zeros((8, 4), jnp.int32)
        mk = jnp.ones((8, 4), bool)
        return fn, (), [
            lambda: ((s0, ix, mk), {}),
            lambda: ((s1, ix, mk), {}),
        ]

    def seg():
        from repro.kernels.seg_softmax.kernel import seg_softmax_pallas

        def fn(e, mask):
            return seg_softmax_pallas(e, mask, block_n=8, interpret=True)

        e0 = jnp.zeros((8, 4), jnp.float32)
        e1 = jnp.ones((8, 4), jnp.float32)
        mk = jnp.ones((8, 4), bool)
        return fn, (), [
            lambda: ((e0, mk), {}),
            lambda: ((e1, mk), {}),
        ]

    return [
        TraceEntry("kernels.gather[interpret]",
                   "src/repro/kernels/gather/kernel.py", gather),
        TraceEntry("kernels.spmm[interpret]",
                   "src/repro/kernels/spmm/kernel.py", spmm),
        TraceEntry("kernels.seg_softmax[interpret]",
                   "src/repro/kernels/seg_softmax/kernel.py", seg),
    ]


def _tiny_graph():
    import numpy as np

    from repro.core.graph import Graph

    rng = np.random.default_rng(0)
    V, E = 64, 256
    src = rng.integers(0, V, E)
    dst = rng.integers(0, V, E)
    return Graph.from_edges(src, dst, num_vertices=V, max_degree=8)


def _graph_entry() -> TraceEntry:
    def build():
        import jax.numpy as jnp

        g = _tiny_graph()

        def fn(seeds):
            return g.neighbor_table(seeds)

        s0 = jnp.arange(8, dtype=jnp.int32)
        s1 = jnp.arange(8, 16, dtype=jnp.int32)
        return fn, (), [
            lambda: ((s0,), {}),
            lambda: ((s1,), {}),
        ]

    return TraceEntry(
        "graph.neighbor_table", "src/repro/core/graph.py", build
    )


def _engine_entry() -> TraceEntry:
    def build():
        import jax.numpy as jnp

        from repro.engine import EngineConfig, MinibatchEngine

        g = _tiny_graph()
        engine = MinibatchEngine.from_config(
            g,
            EngineConfig(
                mode="independent", num_pes=1, local_batch=8, num_layers=2,
                sampler="labor0", fanout=4, schedule="smoothed", kappa=4,
            ),
        )

        def fn(seeds, step):
            # the engine's trace contract: rng_state(step) is dynamic, so
            # one compiled build serves the whole kappa schedule
            return engine.build_plan(seeds, rng=engine.rng_state(step))

        s0 = jnp.arange(8, dtype=jnp.int32)
        s1 = jnp.arange(8, 16, dtype=jnp.int32)
        return fn, (), [
            lambda: ((s0, jnp.int32(0)), {}),
            lambda: ((s1, jnp.int32(1)), {}),
            lambda: ((s0, jnp.int32(7)), {}),  # crosses the kappa window
        ]

    return TraceEntry(
        "engine.build_plan[smoothed]", "src/repro/engine/engine.py", build
    )


def _plan_at_entry() -> TraceEntry:
    def build():
        import jax.numpy as jnp

        from repro.engine import EngineConfig, MinibatchEngine

        g = _tiny_graph()
        engine = MinibatchEngine.from_config(
            g,
            EngineConfig(
                mode="independent", num_pes=2, local_batch=8, num_layers=2,
                sampler="labor0", fanout=4, schedule="nested", kappa=4,
                plan_backend="fused",
            ),
        )

        def fn(step):
            # device-resident plan construction: the hash-permutation seed
            # draw + plan build must compile once and serve every step,
            # including the dynamic within-group sub-batch slice
            return engine.plan_at(step)

        return fn, (), [
            lambda: ((jnp.int32(0),), {}),
            lambda: ((jnp.int32(1),), {}),
            lambda: ((jnp.int32(7),), {}),  # crosses into the next group
        ]

    return TraceEntry(
        "engine.plan_at[nested]", "src/repro/engine/engine.py", build
    )


def _serve_entry() -> TraceEntry:
    def build():
        import jax
        import jax.numpy as jnp

        from repro.data.recsys import make_recsys
        from repro.models.gnn import GNNConfig, init_gnn
        from repro.serve import GNNServer, ServeConfig

        ds = make_recsys(
            num_users=64, num_items=32, edges_per_user=4, feature_dim=32,
            seed=0,
        )
        gnn = GNNConfig(
            model="gcn", num_layers=2, in_dim=32, hidden_dim=32,
            num_classes=ds.num_classes,
        )
        server = GNNServer(
            ds.graph, ds.features, gnn, init_gnn(jax.random.PRNGKey(0), gnn),
            ServeConfig(num_layers=2, fanout=4, max_batch=8, min_bucket=8,
                        use_cache=False),
        )

        def fn(seeds):
            # the serving contract: every same-bucket coalesced batch —
            # regardless of which seeds traffic merged — reuses ONE
            # compiled plan->gather->forward step
            return server.hot_path(seeds)

        s0 = jnp.asarray(ds.user_ids[:8], jnp.int32)
        s1 = jnp.asarray(ds.user_ids[8:16], jnp.int32)
        return fn, (), [
            lambda: ((s0,), {}),
            lambda: ((s1,), {}),
        ]

    return TraceEntry(
        "serve.hot_path[bucket=8]", "src/repro/serve/server.py", build
    )


def default_entries() -> List[TraceEntry]:
    return _kernel_entries() + [
        _graph_entry(), _engine_entry(), _plan_at_entry(), _serve_entry(),
    ]


def run_trace(entries: Iterable[TraceEntry] = None) -> List[Finding]:
    import jax

    findings: List[Finding] = []
    for entry in entries if entries is not None else default_entries():
        try:
            fn, static_argnames, scenarios = entry.build()
        except Exception as e:
            findings.append(Finding(
                rule="RA299", severity=Severity.ERROR,
                message=f"trace harness for `{entry.name}` failed to "
                        f"build: {e!r}",
                file=entry.anchor,
            ))
            continue

        traces = 0

        def counted(*args, __fn=fn, **kwargs):
            nonlocal traces
            traces += 1
            return __fn(*args, **kwargs)

        jitted = jax.jit(counted, static_argnames=static_argnames)
        try:
            # materialize every scenario's inputs up front: argument
            # creation is an *explicit* transfer and must not trip the
            # guard below
            calls = [make() for make in scenarios]
            # first call compiles (constant transfers are legitimate here)
            args, kwargs = calls[0]
            jax.block_until_ready(jitted(*args, **kwargs))
            # subsequent calls must neither retrace nor transfer
            with jax.transfer_guard("disallow"):
                for args, kwargs in calls[1:]:
                    jax.block_until_ready(jitted(*args, **kwargs))
        except TypeError as e:
            if "unhashable" in str(e).lower():
                findings.append(Finding(
                    rule="RA203", severity=Severity.ERROR,
                    message=f"`{entry.name}`: unhashable static argument "
                            f"forces cache misses: {e}",
                    file=entry.anchor,
                ))
            else:
                findings.append(Finding(
                    rule="RA299", severity=Severity.ERROR,
                    message=f"trace harness for `{entry.name}` raised: "
                            f"{e!r}",
                    file=entry.anchor,
                ))
            continue
        except Exception as e:
            if "transfer" in str(e).lower():
                findings.append(Finding(
                    rule="RA202", severity=Severity.ERROR,
                    message=f"`{entry.name}`: implicit host transfer while "
                            f"executing the compiled step: {e}",
                    file=entry.anchor,
                ))
            else:
                findings.append(Finding(
                    rule="RA299", severity=Severity.ERROR,
                    message=f"trace harness for `{entry.name}` raised: "
                            f"{e!r}",
                    file=entry.anchor,
                ))
            continue

        if traces > 1:
            findings.append(Finding(
                rule="RA201", severity=Severity.ERROR,
                message=f"`{entry.name}` recompiled: {traces} traces for "
                        f"{len(scenarios)} calls that must share one "
                        "compilation (check weak-type promotion and "
                        "python-scalar arguments)",
                file=entry.anchor,
                extra=dict(traces=traces, calls=len(scenarios)),
            ))
        else:
            findings.append(Finding(
                rule="RA200", severity=Severity.INFO,
                message=f"`{entry.name}`: 1 trace across {len(scenarios)} "
                        "calls, no implicit transfers",
                file=entry.anchor,
            ))
    return findings
