"""Static invariant checker for plans, Pallas kernels, and trace hygiene.

Three passes, one CLI (``python -m repro.analysis``):

* **lint** (RA0xx, :mod:`repro.analysis.rules`) — AST rules enforcing
  the repo's hot-path contracts: no host sync or numpy inside
  jit/stream scopes, no PRNG key reuse, no Python branching on traced
  values, typed kernel preconditions.
* **contracts** (RA1xx, :mod:`repro.analysis.contracts`) — verifies
  every ``pl.pallas_call`` site's BlockSpec divisibility, index-map
  arity/rank, grid coverage, accumulation-init coverage, and VMEM
  footprint without executing a kernel on device.
* **trace** (RA2xx, :mod:`repro.analysis.trace`) — runs the jit entry
  points on tiny shapes and reports silent recompilations and implicit
  host transfers.

Findings carry stable rule ids and ``file:line`` anchors; severity
gates the exit code.  See README "Static analysis" for the catalog and
inline suppression syntax.
"""
from repro.analysis.cli import main, run_analysis
from repro.analysis.findings import Finding, Report, Severity

__all__ = ["main", "run_analysis", "Finding", "Report", "Severity"]
