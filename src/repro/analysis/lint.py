"""AST lint framework with pluggable repo-specific rules.

The framework owns the mechanics — file discovery, parsing, import-alias
resolution, hot-path scope computation, inline-suppression filtering —
so each rule (see :mod:`repro.analysis.rules`) is a small visitor over a
pre-digested :class:`FileContext`.

Hot-path scopes
---------------
The paper's pipeline only keeps its claimed overlap if the per-step path
stays on-device, so several rules apply only inside *hot* scopes:

* any function decorated with ``jax.jit`` (including
  ``functools.partial(jax.jit, ...)`` and ``jax.jit(...)`` decorator
  forms) and every function nested within one — these trace, so host
  ops there are either silently baked-in constants or trace errors;
* methods of classes named in :data:`HOT_CLASSES` (the streaming
  pipeline: a host sync inside ``MinibatchStream`` serializes exactly
  the prefetch it exists to provide).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional

from repro.analysis.findings import Finding, Severity, is_suppressed

#: Classes whose methods count as hot paths even without @jax.jit.
HOT_CLASSES = frozenset({"MinibatchStream"})


# --- import alias resolution ----------------------------------------------

@dataclass
class ImportMap:
    """Maps local names to fully-qualified module paths.

    ``import numpy as np``           -> {"np": "numpy"}
    ``from jax import random``       -> {"random": "jax.random"}
    ``import jax.numpy as jnp``      -> {"jnp": "jax.numpy"}
    ``from jax.experimental import pallas as pl`` -> {"pl": "jax.experimental.pallas"}
    """

    names: dict = field(default_factory=dict)

    def collect(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.names[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.names[a.asname or a.name] = f"{node.module}.{a.name}"

    def qualify(self, node: ast.AST) -> Optional[str]:
        """Dotted name of an expression like ``np.asarray`` / ``jax.jit``,
        with the leading alias expanded; None for non-name expressions."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.names.get(node.id, node.id))
        return ".".join(reversed(parts))


# --- per-file context ------------------------------------------------------

@dataclass
class FileContext:
    path: str
    source: str
    source_lines: list
    tree: ast.AST
    imports: ImportMap
    #: FunctionDef/AsyncFunctionDef nodes considered hot (jit or stream).
    hot_functions: set = field(default_factory=set)
    #: all function nodes, in source order
    functions: list = field(default_factory=list)
    #: maps each node id() to its enclosing function node (or None)
    enclosing: dict = field(default_factory=dict)

    def is_hot(self, node: ast.AST) -> bool:
        """True when ``node`` sits inside a hot function scope."""
        fn = self.enclosing.get(id(node))
        while fn is not None:
            if fn in self.hot_functions:
                return True
            fn = self.enclosing.get(id(fn))
        return False

    def qualify(self, node: ast.AST) -> Optional[str]:
        return self.imports.qualify(node)


_FUNCTION_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _is_jit_decorator(dec: ast.AST, imports: ImportMap) -> bool:
    """Matches @jax.jit, @jit, @jax.jit(...), @partial(jax.jit, ...)."""
    if isinstance(dec, ast.Call):
        q = imports.qualify(dec.func)
        if q in ("jax.jit", "jax.api.jit"):
            return True
        if q in ("functools.partial", "partial") and dec.args:
            return imports.qualify(dec.args[0]) in ("jax.jit", "jax.api.jit")
        return False
    return imports.qualify(dec) in ("jax.jit", "jax.api.jit")


def build_context(path: str, source: str) -> FileContext:
    tree = ast.parse(source, filename=path)
    imports = ImportMap()
    imports.collect(tree)
    ctx = FileContext(
        path=path,
        source=source,
        source_lines=source.splitlines(),
        tree=tree,
        imports=imports,
    )

    # enclosing-function map + function list (source order via ast.walk
    # is fine: we only need ancestry, not order, for hotness)
    def visit(node: ast.AST, fn: Optional[ast.AST], cls: Optional[ast.AST]):
        for child in ast.iter_child_nodes(node):
            child_fn, child_cls = fn, cls
            if isinstance(child, _FUNCTION_NODES):
                ctx.functions.append(child)
                ctx.enclosing[id(child)] = fn
                if any(
                    _is_jit_decorator(d, imports) for d in child.decorator_list
                ):
                    ctx.hot_functions.add(child)
                elif fn in ctx.hot_functions or (
                    cls is not None and cls.name in HOT_CLASSES and fn is None
                ):
                    ctx.hot_functions.add(child)
                child_fn, child_cls = child, None
            elif isinstance(child, ast.ClassDef):
                ctx.enclosing[id(child)] = fn
                child_cls = child
            else:
                ctx.enclosing[id(child)] = fn
            visit(child, child_fn, child_cls)

    visit(tree, None, None)

    # nested functions of hot functions are hot too (second pass: a
    # nested def may precede its parent's classification only when the
    # parent was classified by class membership — ancestry check in
    # is_hot already climbs, so nothing more to do here).
    return ctx


# --- rule base -------------------------------------------------------------

class LintRule:
    """Base class: subclass, set ``rule_id``/``severity``, implement check."""

    rule_id: str = "RA000"
    severity: Severity = Severity.ERROR
    title: str = ""

    def check(self, ctx: FileContext) -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(
        self, ctx: FileContext, node: ast.AST, message: str, **extra
    ) -> Finding:
        return Finding(
            rule=self.rule_id,
            severity=self.severity,
            message=message,
            file=ctx.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            extra=extra,
        )


# --- runner ----------------------------------------------------------------

def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if not d.startswith(".") and d != "__pycache__"
            )
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def run_lint(
    paths: Iterable[str], rules: Optional[list] = None
) -> tuple:
    """Run lint rules over ``paths``; returns (findings, files_scanned)."""
    if rules is None:
        from repro.analysis.rules import default_rules

        rules = default_rules()
    findings = []
    n_files = 0
    for path in iter_python_files(paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
            ctx = build_context(path, source)
        except (OSError, SyntaxError) as e:
            findings.append(
                Finding(
                    rule="RA999",
                    severity=Severity.ERROR,
                    message=f"could not parse file: {e}",
                    file=path,
                    line=getattr(e, "lineno", 0) or 0,
                )
            )
            continue
        n_files += 1
        for rule in rules:
            for f in rule.check(ctx):
                if not is_suppressed(f, ctx.source_lines):
                    findings.append(f)
    return findings, n_files
