"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.models.transformer.config import ArchConfig

ALL_ARCHS = (
    "mamba2-2.7b",
    "granite-3-8b",
    "whisper-tiny",
    "gemma2-2b",
    "nemotron-4-15b",
    "internvl2-26b",
    "gemma3-27b",
    "hymba-1.5b",
    "grok-1-314b",
    "llama4-scout-17b-a16e",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_") for name in ALL_ARCHS}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_archs() -> tuple[str, ...]:
    return ALL_ARCHS
