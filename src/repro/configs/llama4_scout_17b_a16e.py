"""llama4-scout-17b-a16e — 16-expert top-1 MoE with early-fusion vision.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48 layers, d_model 5120, 40 query
heads / 8 KV heads, MoE d_ff 8192 with 16 experts top-1, vocab 202048.
Early fusion: image patch embeddings (STUB per the brief) are prepended
to the token stream as 64 prefix tokens.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("global",),
    num_experts=16,
    moe_top_k=1,
    activation="silu",
    gated_mlp=True,
    frontend="vision",
    num_prefix_tokens=64,
    tie_embeddings=False,
)
