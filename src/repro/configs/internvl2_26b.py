"""internvl2-26b — VLM: InternViT frontend (stub) + InternLM2 decoder.

[arXiv:2404.16821] InternVL 1.5/2 series.  Language backbone: 48 layers,
d_model 6144, 48 query heads / 8 KV heads, SwiGLU d_ff 16384, vocab
92553.  The InternViT vision encoder + MLP projector is a STUB per the
brief: ``prefix_embeds`` carries 64 precomputed patch embeddings
prepended to the token sequence.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    layer_pattern=("global",),
    activation="silu",
    gated_mlp=True,
    frontend="vision",
    num_prefix_tokens=64,
)
