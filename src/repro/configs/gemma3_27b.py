"""gemma3-27b — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family, 27b shape] 62 layers, d_model 5376,
32 query heads (head_dim 128) / 16 KV heads, GeGLU d_ff 21504, vocab
262144; every 6th layer is global (1M rope theta), others sliding
window 1024.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    activation="gelu",
    gated_mlp=True,
)
