"""nemotron-4-15b — dense GQA decoder with squared-ReLU MLP.

[arXiv:2402.16819] Nemotron-4 15B: 32 layers, d_model 6144, 48 query
heads / 8 KV heads (GQA), d_ff 24576 with squared-ReLU (non-gated),
vocab 256000.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    layer_pattern=("global",),
    activation="relu2",
    gated_mlp=False,
    tie_embeddings=False,
)
