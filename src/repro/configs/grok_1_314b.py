"""grok-1-314b — 8-expert top-2 MoE decoder.

[hf:xai-org/grok-1] 64 layers, d_model 6144, 48 query heads / 8 KV
heads, MoE d_ff 32768 with 8 experts top-2, vocab 131072.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    layer_pattern=("global",),
    num_experts=8,
    moe_top_k=2,
    activation="gelu",
    gated_mlp=True,
    tie_embeddings=False,
)
