"""gemma2-2b — local/global alternating attention with logit softcaps.

[arXiv:2408.00118] Gemma 2.  2B: 26 layers, d_model 2304, 8 query heads
(head_dim 256) / 4 KV heads, GeGLU d_ff 9216, vocab 256000, sliding
window 4096 on alternating layers, attn softcap 50, final logit
softcap 30.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    activation="gelu",
    gated_mlp=True,
)
