"""granite-3-8b — dense GQA decoder.

[hf:ibm-granite/granite-3.0-2b-base family, 8b shape] 40 layers,
d_model 4096, 32 query heads / 8 KV heads (GQA), SwiGLU d_ff 12800,
vocab 49155.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    layer_pattern=("global",),
    activation="silu",
    gated_mlp=True,
)
