"""whisper-tiny — encoder-decoder audio backbone (decoder implemented).

[arXiv:2212.04356] Robust Speech Recognition via Large-Scale Weak
Supervision.  Tiny: 4 layers, d_model 384, 6 heads (MHA: kv=6),
d_ff 1536, vocab 51865.  The mel-spectrogram + conv frontend is a STUB
per the brief: ``enc_out`` carries precomputed frame embeddings
(enc_len 1500); the decoder cross-attends to them.  RoPE replaces
learned absolute positions (TPU-backbone adaptation, DESIGN.md).
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    layer_pattern=("global",),
    activation="gelu",
    gated_mlp=False,
    enc_dec=True,
    enc_len=1500,
    frontend="audio",
)
