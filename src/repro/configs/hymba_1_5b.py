"""hymba-1.5b — hybrid-head: parallel attention + Mamba heads per layer.

[arXiv:2411.13676] Hymba: 32 layers, d_model 1600, 25 query heads /
5 KV heads (head_dim 64), SwiGLU d_ff 5504, vocab 32001, SSM state 16.
Attention is sliding-window (1024) in all but 3 full-attention layers
(first / middle / last), fused with the SSD path by averaging — the
published "parallel hybrid head" topology.
"""
from repro.models.transformer.config import ArchConfig

# Pattern period 16 (scan-friendly): full-attention layers land at
# depths 0 and 16 (paper places 3 at first/middle/last; we keep
# first/middle and window the last — documented approximation).
_pattern = ("hybrid_global",) + ("hybrid",) * 15

CONFIG = ArchConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    layer_pattern=_pattern,
    window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=256,
    activation="silu",
    gated_mlp=True,
)
