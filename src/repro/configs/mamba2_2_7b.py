"""mamba2-2.7b — attention-free SSD state-space model.

[arXiv:2405.21060] Transformers are SSMs (Mamba-2), 2.7B config:
64 layers, d_model 2560, d_state 128, attention-free, no MLP (d_ff=0),
GPT-NeoX vocab 50280.  d_inner = 2*d = 5120, 80 SSD heads of dim 64.
"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)
