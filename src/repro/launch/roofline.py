"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs_per_device   / 197e12  FLOP/s (bf16)
    memory     = HLO_bytes_per_device   / 819e9   B/s HBM
    collective = coll_bytes_per_device  / 50e9    B/s ICI per link

``compiled.cost_analysis()`` reports the per-device SPMD program, so the
per-device form above equals the brief's global/(chips × peak) form.
Collective bytes are parsed from the optimized HLO: operand bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (they are not in cost_analysis).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in (optimized) HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # `%name = <out shapes> <op>(<operands>), ...`
        rhs = s.split("=", 1)[1]
        op = None
        for c in _COLLECTIVES:
            # match the op name at call position (avoid metadata mentions)
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue  # bytes already counted at the -start op
        paren = rhs.index("(")
        operand_text = rhs[paren:]
        out_text = rhs[:paren]
        operand_bytes = sum(
            _shape_bytes(m.group(1), m.group(2))
            for m in _SHAPE_RE.finditer(operand_text)
        )
        if operand_bytes == 0:  # older HLO w/o inline operand types
            operand_bytes = sum(
                _shape_bytes(m.group(1), m.group(2))
                for m in _SHAPE_RE.finditer(out_text)
            )
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + operand_bytes
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    useful_ratio: float
    coll_detail: dict
    peak_mem_bytes: float

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_global": self.model_flops_global,
            "useful_ratio": self.useful_ratio,
            "coll_detail": self.coll_detail,
            "peak_mem_bytes": self.peak_mem_bytes,
        }


def analyze(compiled, num_devices: int, model_flops_global: float) -> Roofline:
    """Trip-count-weighted roofline terms from the compiled SPMD module.

    Raw ``cost_analysis()`` counts scan bodies once; the HLO walk in
    ``hlo_analysis`` re-weights by ``known_trip_count`` so scanned layer
    units / flash key-blocks / CE chunks are charged per execution.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some jax versions return [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_hbm = float(cost.get("bytes accessed", 0.0))
    hlo = analyze_hlo(compiled.as_text())
    flops = max(hlo.dot_flops, raw_flops)
    hbm = max(hlo.hbm_bytes, raw_hbm)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = hlo.coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mem_stats = compiled.memory_analysis()
    peak = float(
        getattr(mem_stats, "temp_size_in_bytes", 0)
        + getattr(mem_stats, "argument_size_in_bytes", 0)
        + getattr(mem_stats, "output_size_in_bytes", 0)
        - getattr(mem_stats, "alias_size_in_bytes", 0)
    )
    useful = model_flops_global / max(flops * num_devices, 1.0)
    return Roofline(
        flops_per_dev=flops,
        hbm_bytes_per_dev=hbm,
        coll_bytes_per_dev=float(hlo.coll_bytes),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        coll_detail=hlo.coll_detail,
        peak_mem_bytes=peak,
    )


def model_flops(cfg, shape_spec, active_params: int) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode)."""
    B, S = shape_spec.global_batch, shape_spec.seq_len
    if shape_spec.kind == "train":
        return 6.0 * active_params * B * S
    if shape_spec.kind == "prefill":
        return 2.0 * active_params * B * S
    return 2.0 * active_params * B  # decode: one token per sequence
