"""Step-function factories for the architecture pool.

``make_train_step``   — next-token CE + MoE aux loss + Adam update.
``make_prefill_step`` — inference forward over the full prompt.
``make_serve_step``   — ONE new token against a KV/SSM cache.

All are pure functions of (params, [opt_state | state], batch) suitable
for ``jax.jit(...).lower(...)`` in the dry-run and for real training in
the examples.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    forward_decode,
    forward_prefill,
)
from repro.models.transformer.config import ArchConfig
from repro.models.transformer.model import _unembed, forward_hidden
from repro.train.optim import adam_update


def _chunked_ce(cfg: ArchConfig, params, h: jax.Array, labels: jax.Array,
                chunk: int = 512) -> jax.Array:
    """Next-token CE computed in sequence chunks.

    Materializing full (B, S, V) logits at the assigned shapes is
    O(100 TB) global (train_4k × 49k-262k vocabs); chunking caps the
    live logits tensor at (B, chunk, V) and lets XLA reuse the buffer
    across chunks.  ``jax.checkpoint`` keeps the backward pass chunked
    too (logits recomputed per chunk).
    """
    B, S, d = h.shape
    c = min(chunk, S)
    n = S // c
    rem = S - n * c

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        logits = _unembed(params, cfg, h_c).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - ll)

    hs = jnp.moveaxis(h[:, : n * c].reshape(B, n, c, d), 1, 0)
    ys = jnp.moveaxis(labels[:, : n * c].reshape(B, n, c), 1, 0)

    def body(acc, xs):
        h_c, y_c = xs
        return acc + chunk_loss(h_c, y_c), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    if rem:
        total = total + chunk_loss(h[:, n * c :], labels[:, n * c :])
    return total / (B * S)


def lm_loss(cfg: ArchConfig, params, batch, ce_chunk: int = 512) -> jax.Array:
    """Mean next-token CE over text positions (+ MoE load-balance aux)."""
    h, aux = forward_hidden(
        params,
        cfg,
        batch["tokens"],
        batch.get("prefix_embeds"),
        batch.get("enc_out"),
    )
    h = h[:, cfg.num_prefix_tokens :, :]
    ce = _chunked_ce(cfg, params, h, batch["labels"], chunk=ce_chunk)
    return ce + 0.01 * aux


def make_train_step(cfg: ArchConfig, lr: float = 1e-3) -> Callable:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, opt_state = adam_update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        logits, _ = forward_prefill(
            params,
            cfg,
            batch["tokens"],
            batch.get("prefix_embeds"),
            batch.get("enc_out"),
        )
        return logits  # (B, V) last-position logits

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, state, token):
        logits, state = forward_decode(params, cfg, state, token)
        return logits, state

    return serve_step
