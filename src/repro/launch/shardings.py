"""GSPMD sharding rules for the architecture pool.

Megatron-style tensor parallelism on the ``model`` axis, batch data
parallelism on (``pod``,) ``data``; divisibility-gated: a dim is only
sharded if it divides evenly by the axis size, otherwise replicated
(whisper-tiny's 6 heads on a 16-way model axis replicate, its d_ff
shards).  Optimizer moments additionally shard their first replicated
dim over ``data`` (ZeRO-1) so grok-1-scale state fits.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name]))
    return mesh.shape[name]


def _fit(mesh: Mesh, shape: tuple[int, ...], spec: P) -> P:
    """Drop sharding on dims that do not divide evenly."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(ax if ax and dim % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


def _param_spec(
    path: str, shape: tuple[int, ...], mesh: Mesh, moe_fsdp: bool = False
) -> P:
    """Sharding rule for a parameter tensor by name.

    Rules address *trailing* dims so the scan-stacked layout (leading
    unit axis U on every block parameter) shards identically to the
    unstacked one: leading dims are padded with None.
    """
    leaf = path.split("/")[-1]
    nd = len(shape)
    dsz = mesh.shape.get("data", 1)

    def trailing(*axes) -> P:
        return P(*([None] * (nd - len(axes)) + list(axes)))

    if leaf == "embed":
        return P("model", None)          # (V, d): shard vocab
    if leaf == "unembed":
        return P(None, "model")
    if nd >= 4 and leaf in ("w_up", "w_gate", "w_down"):
        # MoE expert weights (U, E, d, f) / (U, E, f, d): tensor-parallel
        # on the ff dim PLUS either expert-parallel (E % data == 0) or
        # FSDP on the other matmul dim — grok-scale expert stacks cannot
        # live model-sharded only.
        E = shape[-3]
        tp = ("model", None) if leaf == "w_down" else (None, "model")
        if E % dsz == 0 and not moe_fsdp:
            return P(*([None] * (nd - 3) + ["data", *tp]))
        # E not divisible (grok's 8 experts on a 16-way data axis): FSDP
        # on the other matmul dim.  2-D f-over-(data×model) TP was tried
        # and REFUTED (§Perf grok iter-3): it conflicts with the token
        # groups' data sharding and triggers resharding storms.
        fsdp = (tp[0], "data") if tp[0] == "model" else ("data", tp[1])
        return P(*([None] * (nd - 3) + [None, *fsdp]))
    if leaf in ("wq", "wk", "wv", "w_up", "w_gate", "w_in", "conv_w"):
        return trailing(None, "model")   # column parallel
    if leaf in ("wo", "w_down", "w_out"):
        return trailing("model", None)   # row parallel
    if leaf in ("A_log", "D", "dt_bias") and shape[-1] > 1:
        return trailing("model")         # SSD heads
    if leaf == "router":
        return trailing(None, None)
    return P(*([None] * nd))             # norms, biases: replicated


def param_shardings(mesh: Mesh, params, moe_fsdp: bool = False) -> object:
    """NamedSharding pytree matching ``params``.

    ``moe_fsdp=True`` forces FSDP sharding for expert weights even when
    expert-parallel placement is possible (§Perf experiment).
    """

    def one(path_keys, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        shape = np.shape(leaf)
        return NamedSharding(
            mesh, _fit(mesh, shape, _param_spec(path, shape, mesh, moe_fsdp))
        )

    return jax.tree_util.tree_map_with_path(one, params)


def opt_shardings(mesh: Mesh, params) -> object:
    """ZeRO-1: moments shard the first unsharded dim over the batch axes."""
    b_axes = batch_axes(mesh)

    def one(path_keys, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        shape = np.shape(leaf)
        spec = list(_fit(mesh, shape, _param_spec(path, shape, mesh)))
        spec += [None] * (len(shape) - len(spec))
        used = {
            a
            for ax in spec
            if ax
            for a in (ax if isinstance(ax, tuple) else (ax,))
        }
        if not (set(b_axes) & used):
            for i, (dim, ax) in enumerate(zip(shape, spec)):
                if ax is None and dim % _axis_size(mesh, b_axes) == 0 and dim > 1:
                    spec[i] = b_axes
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


def data_spec(mesh: Mesh, shape: tuple[int, ...], batch_dim: int = 0) -> P:
    """Batch-sharded activation spec; falls back to replication."""
    b_axes = batch_axes(mesh)
    spec = [None] * len(shape)
    if shape[batch_dim] % _axis_size(mesh, b_axes) == 0:
        spec[batch_dim] = b_axes
    return P(*spec)


def decode_state_shardings(mesh: Mesh, state) -> object:
    """KV/SSM cache shardings for serving.

    Batch dim shards over the batch axes when divisible; otherwise (the
    long-context batch=1 shape) KV caches shard their *sequence* dim over
    ``data`` — GSPMD inserts the softmax cross-shard reductions.
    """
    b_axes = batch_axes(mesh)
    bsz = _axis_size(mesh, b_axes)

    def one(path_keys, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        shape = np.shape(leaf)
        if shape == ():
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        leaf_name = path.split("/")[-1]
        msz = mesh.shape.get("model", 1)
        if shape[0] % bsz == 0 and shape[0] > 1:
            spec[0] = b_axes
        elif leaf_name in ("k", "v") and len(shape) == 4 and shape[1] % mesh.shape["data"] == 0:
            spec[1] = "data"  # batch=1 long-context: shard cache sequence dim
        if leaf_name in ("k", "v") and len(shape) == 4:
            if shape[2] % msz == 0 and shape[2] > 1:
                spec[2] = "model"      # KV heads
            elif shape[3] % msz == 0:
                spec[3] = "model"      # head_dim fallback (kv < model size)
        if leaf_name == "h" and len(shape) == 4 and shape[1] % msz == 0:
            spec[1] = "model"          # SSD heads
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
