import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
    PYTHONPATH=src python -m repro.launch.dryrun --gnn   # the paper's own pipeline

The XLA_FLAGS line above MUST precede any jax import: it materializes
512 host placeholder devices so ``jax.make_mesh`` can build the
production meshes (16x16 single pod / 2x16x16 two pods).

Each combo writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
with memory analysis, cost analysis and roofline terms (§Roofline).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ALL_ARCHS, get_config  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shardings import (  # noqa: E402
    data_spec,
    decode_state_shardings,
    opt_shardings,
    param_shardings,
)
from repro.launch.specs import (  # noqa: E402
    SHAPES,
    batch_specs,
    decode_state_specs,
    opt_specs,
    params_specs,
    shape_applicable,
)
from repro.launch.steps import (  # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.transformer.config import active_param_count  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v.lower() in ("true", "false"):
        return k, v.lower() == "true"
    try:
        return k, int(v)
    except ValueError:
        try:
            return k, float(v)
        except ValueError:
            return k, v


def lower_combo(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    overrides: dict | None = None,
    tag: str = "",
):
    n_batch_shards = 32 if multi_pod else 16
    overrides = dict(overrides or {})
    moe_fsdp = overrides.pop("moe_fsdp", False)  # sharding-rule switch
    cfg = dataclasses.replace(
        get_config(arch), dtype="bfloat16", moe_groups=n_batch_shards,
        **overrides,
    )
    spec = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.transformer.modules import set_logical_mesh

    set_logical_mesh(mesh)
    t0 = time.time()
    params_s = params_specs(cfg)
    p_sh = param_shardings(mesh, params_s, moe_fsdp=moe_fsdp)

    with mesh:
        if spec.kind == "train":
            step = make_train_step(cfg)
            opt_s = opt_specs(params_s)
            from repro.train.optim import AdamState

            opt_sh = AdamState(
                step=NamedSharding(mesh, P()),
                mu=opt_shardings(mesh, params_s),
                nu=opt_shardings(mesh, params_s),
            )
            b_specs = batch_specs(cfg, spec)
            b_sh = {
                k: NamedSharding(mesh, data_spec(mesh, v.shape))
                for k, v in b_specs.items()
            }
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, b_sh),
                out_shardings=(p_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_s, opt_s, b_specs)
        elif spec.kind == "prefill":
            step = make_prefill_step(cfg)
            b_specs = batch_specs(cfg, spec)
            b_sh = {
                k: NamedSharding(mesh, data_spec(mesh, v.shape))
                for k, v in b_specs.items()
            }
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_s, b_specs)
        else:  # decode
            step = make_serve_step(cfg)
            state_s = decode_state_specs(cfg, spec)
            s_sh = decode_state_shardings(mesh, state_s)
            tok_s = batch_specs(cfg, spec)["token"]
            tok_sh = NamedSharding(mesh, data_spec(mesh, tok_s.shape))
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, s_sh, tok_sh),
                out_shardings=(None, s_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_s, state_s, tok_s)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    n_dev = mesh.size
    mf = rl.model_flops(cfg, spec, active_param_count(get_config(arch)))
    roof = rl.analyze(compiled, n_dev, mf)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(multi_pod),
        "tag": tag,
        "overrides": {**overrides, **({"moe_fsdp": True} if moe_fsdp else {})},
        "status": "ok",
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_per_device_gb": roof.peak_mem_bytes / 2**30,
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(
            f"[{arch} | {shape_name} | {result['mesh']}] ok "
            f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
            f"peak/dev {result['memory']['peak_per_device_gb']:.2f} GiB "
            f"bottleneck={roof.bottleneck} "
            f"(c={roof.compute_s*1e3:.2f}ms m={roof.memory_s*1e3:.2f}ms "
            f"coll={roof.collective_s*1e3:.2f}ms) useful={roof.useful_ratio:.2f}",
            flush=True,
        )
    return result


def run_gnn_dryrun(multi_pod: bool = False, verbose: bool = True,
                   overrides: dict | None = None, tag: str = ""):
    """Lower the paper's own cooperative GNN train step on the mesh.

    PEs = all mesh devices (the paper's cooperation domain); graph is
    block-partitioned so each PE's feature shard is a contiguous row
    block (production feature stores are owner-partitioned the same way).
    """
    from repro.launch.gnn_dryrun import lower_gnn_coop_step

    return lower_gnn_coop_step(
        multi_pod=multi_pod, verbose=verbose, tag=tag, **(overrides or {})
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gnn", action="store_true")
    ap.add_argument(
        "--multi-pod", default="single", choices=["single", "multi", "both"]
    )
    ap.add_argument(
        "--set", action="append", default=[], dest="overrides",
        help="config override key=value (hillclimb experiments)",
    )
    ap.add_argument("--tag", default="", help="suffix for the result json")
    args = ap.parse_args()
    overrides = dict(_parse_override(kv) for kv in args.overrides)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]
    os.makedirs(OUT_DIR, exist_ok=True)
    results = []
    if args.gnn:
        for mp in meshes:
            results.append(
                run_gnn_dryrun(multi_pod=mp, overrides=overrides, tag=args.tag)
            )
    else:
        archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
        shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        results.append(
                            lower_combo(arch, shape, mp, overrides=overrides,
                                        tag=args.tag)
                        )
                    except Exception as e:  # a failure here is a bug: record it
                        traceback.print_exc()
                        results.append(
                            {"arch": arch, "shape": shape,
                             "mesh": _mesh_tag(mp), "status": "error",
                             "error": repr(e)}
                        )
    for r in results:
        name = f"{r.get('arch','gnn')}__{r.get('shape','coop')}__{r['mesh']}"
        if args.tag:
            name += f"__{args.tag}"
        with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
            json.dump(r, f, indent=2)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
