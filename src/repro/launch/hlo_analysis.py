"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, but our
production layout scans over layer units (and flash-attention scans over
key blocks), so FLOPs / bytes / collective volumes would be undercounted
by the trip count.  XLA annotates static loops with
``backend_config={"known_trip_count":{"n":...}}``; this module rebuilds
the call-graph multipliers and sums per-instruction costs weighted by
how often they actually execute.

Extracted (per device, matmul-dominated lower bounds):
  * dot FLOPs:        2 * prod(out_shape) * prod(lhs contracting dims)
  * HBM bytes:        dot operands+outputs, gather/scatter/dus outputs
                      (weights re-read every loop iteration — faithful to
                      TPU execution of scanned layers)
  * collective bytes: operand bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute

Elementwise FLOPs are ignored (documented; matmul terms dominate every
arch in the pool).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128)"
    r"\[([0-9,]*)\]"
)
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes(text: str):
    for m in _SHAPE_RE.finditer(text):
        dims = [int(x) for x in m.group(2).split(",") if x]
        n = 1
        for d in dims:
            n *= d
        yield m.group(1), dims, n * _DTYPE_BYTES[m.group(1)]


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0


def _split_computations(txt: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        s = line.rstrip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", s.strip())
        if m and not s.strip().startswith("%param"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s.strip())
    return comps


def _entry_name(txt: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    return m.group(1) if m else None


def _build_multipliers(comps: dict[str, list[str]], entry: str) -> tuple[dict, int]:
    mult = {entry: 1.0}
    unknown = 0
    work = [entry]
    seen = set()
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        m = mult.get(name, 1.0)
        for line in comps.get(name, ()):
            if " while(" in line or re.search(r"=\s*\([^)]*\)\s*while\(", line):
                trip = _TRIP_RE.search(line)
                n = int(trip.group(1)) if trip else 1
                if not trip:
                    unknown += 1
                body = _CALLED_RE.search(line)
                cond = _COND_RE.search(line)
                if body:
                    mult[body.group(1)] = mult.get(body.group(1), 0.0) + m * n
                    work.append(body.group(1))
                if cond:
                    mult[cond.group(1)] = mult.get(cond.group(1), 0.0) + m * (n + 1)
                    work.append(cond.group(1))
            else:
                for callee in _CALLED_RE.finditer(line):
                    c = callee.group(1)
                    mult[c] = mult.get(c, 0.0) + m
                    work.append(c)
    return mult, unknown


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _build_symbols(txt: str) -> dict[str, tuple[str, list[int], int]]:
    """Instruction name -> (dtype, dims, bytes); names are module-unique."""
    table: dict[str, tuple[str, list[int], int]] = {}
    for line in txt.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = list(_shapes(m.group(2).split("(", 1)[0]))
        if len(shapes) == 1:
            table[m.group(1)] = shapes[0]
        elif len(shapes) > 1:  # tuple-typed (while, rng...): record total bytes
            total = sum(b for _, _, b in shapes)
            table[m.group(1)] = ("tuple", [], total)
    return table


def _split_top_level(inner: str) -> list[str]:
    """Split an operand list on commas OUTSIDE (), [], {}.

    Optimized-HLO operands carry inline types — ``f32[64,64]{1,0} %x`` —
    whose shape/layout commas must not split the list.
    """
    parts, cur, depth = [], [], 0
    for ch in inner:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_names(rhs: str, start: int | None = None) -> list[str]:
    """Names inside the op's call parens.

    ``start``: index of the opening paren of the CALL (tuple-typed ops
    like ``(s32[..], ...) all-to-all(%a, %b)`` have earlier parens that
    belong to the type, so callers locate the op name first).
    """
    if start is None:
        start = rhs.index("(")
    depth = 0
    end = start
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = rhs[start + 1 : end]
    names = []
    for tok in _split_top_level(inner):
        # the name is the trailing token; a leading inline type is optional
        m = re.search(r"%([\w.\-]+)\s*$", tok) or re.match(
            r"\s*([a-zA-Z_][\w.\-]*)\s*$", tok
        )
        if m:
            names.append(m.group(1))
    return names


def _dot_flops(line: str, table: dict) -> tuple[float, float]:
    """Returns (flops, bytes) for one dot instruction."""
    rhs = line.split("=", 1)[1]
    out = list(_shapes(rhs.split("(", 1)[0]))
    if not out:
        return 0.0, 0.0
    out_elems = 1
    for d in out[0][1]:
        out_elems *= d
    names = _operand_names(rhs)
    lhs = table.get(names[0]) if names else None
    k = 1
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if lc and lhs:
        for idx in (int(x) for x in lc.group(1).split(",") if x):
            if idx < len(lhs[1]):
                k *= lhs[1][idx]
    op_bytes = sum(table[n][2] for n in names if n in table)
    return 2.0 * out_elems * k, op_bytes + out[0][2]


def analyze_hlo(txt: str) -> HloCosts:
    comps = _split_computations(txt)
    entry = _entry_name(txt)
    if entry is None or entry not in comps:
        # fall back: treat whole text as one computation
        comps = {"__all__": txt.splitlines()}
        entry = "__all__"
    mult, unknown = _build_multipliers(comps, entry)
    table = _build_symbols(txt)
    costs = HloCosts(unknown_trip_loops=unknown)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            m = 1.0 if name == entry else 0.0
        if m == 0.0:
            continue
        for line in lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1]
            if re.search(r"\bdot\(", rhs):
                fl, by = _dot_flops(line, table)
                costs.dot_flops += m * fl
                costs.hbm_bytes += m * by
                continue
            gm = re.search(r"\b(gather|scatter|dynamic-update-slice)\(", rhs)
            if gm and "get-tuple-element" not in rhs[: gm.start()]:
                out_b = sum(b for _, _, b in _shapes(rhs[: rhs.index("(")]))
                costs.hbm_bytes += m * out_b
                continue
            for c in _COLLECTIVES:
                cm = re.search(rf"\b{c}(-start)?\(", rhs)
                if cm:
                    call_paren = rhs.index("(", cm.start())
                    names = _operand_names(rhs, call_paren)
                    b = sum(table[n][2] for n in names if n in table)
                    if b == 0:  # fall back to the (tuple) output shapes
                        b = sum(x for _, _, x in _shapes(rhs[: cm.start()]))
                    costs.coll_bytes += m * b
                    costs.hbm_bytes += m * b
                    d = costs.coll_detail.setdefault(c, {"bytes": 0.0, "count": 0.0})
                    d["bytes"] += m * b
                    d["count"] += m
                    break
    return costs
