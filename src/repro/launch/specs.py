"""ShapeDtypeStruct input specs per (architecture x input shape).

The assigned input-shape grid:

    train_4k      seq  4,096  global_batch 256   train_step
    prefill_32k   seq 32,768  global_batch  32   prefill_step
    decode_32k    seq 32,768  global_batch 128   serve_step (1 token)
    long_500k     seq 524,288 global_batch   1   serve_step (1 token)

``long_500k`` is only generated for sub-quadratic-capable archs (SSM /
hybrid / native sliding-window); pure full-attention archs skip it
(DESIGN.md §5).  Audio/VLM frontends appear as precomputed embedding
specs (the sanctioned stub).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.transformer import init_decode_state, init_lm
from repro.models.transformer.config import ArchConfig
from repro.train.optim import AdamState


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs that may run long_500k (sub-quadratic or native sliding-window)
LONG_CONTEXT_OK = {"mamba2-2.7b", "hymba-1.5b", "gemma2-2b", "gemma3-27b"}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.name.replace("-smoke", "") not in LONG_CONTEXT_OK:
        return False, "full-attention stack; long-context decode skipped (DESIGN.md §5)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, spec: ShapeSpec) -> dict:
    """Input ShapeDtypeStructs for the step named by ``spec.kind``."""
    B, S = spec.global_batch, spec.seq_len
    dt = cfg.jdtype
    if spec.kind in ("train", "prefill"):
        s_text = S - cfg.num_prefix_tokens
        batch = {"tokens": _sds((B, s_text), jnp.int32)}
        if spec.kind == "train":
            batch["labels"] = _sds((B, s_text), jnp.int32)
        if cfg.num_prefix_tokens:
            batch["prefix_embeds"] = _sds((B, cfg.num_prefix_tokens, cfg.d_model), dt)
        if cfg.enc_dec:
            batch["enc_out"] = _sds((B, cfg.enc_len, cfg.d_model), dt)
        return batch
    # decode: one token + pre-sized caches
    return {"token": _sds((B, 1), jnp.int32)}


def params_specs(cfg: ArchConfig) -> dict:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))


def opt_specs(params_s) -> AdamState:
    def mom(s):
        dt = jnp.float32 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)

    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(mom, params_s),
        nu=jax.tree.map(mom, params_s),
    )


def decode_state_specs(cfg: ArchConfig, spec: ShapeSpec) -> dict:
    return jax.eval_shape(
        lambda: init_decode_state(cfg, spec.global_batch, spec.seq_len)
    )
