"""Training launcher.

GNN (the paper's system):
    PYTHONPATH=src python -m repro.launch.train gnn \
        --mode cooperative --pes 4 --steps 100 --kappa 16

LM pool (reduced configs on CPU; full configs are dry-run-only):
    PYTHONPATH=src python -m repro.launch.train lm --arch granite-3-8b \
        --steps 5 --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def run_gnn(args) -> None:
    from repro.data import rmat_graph
    from repro.data.synthetic import SyntheticGraphDataset
    from repro.models.gnn import GNNConfig
    from repro.train.loop import TrainConfig, evaluate, train_gnn

    graph = rmat_graph(scale=args.scale, edge_factor=8, max_degree=32, seed=0)
    ds = SyntheticGraphDataset(graph, feature_dim=64, num_classes=16, seed=0)
    cfg = GNNConfig(model=args.model, num_layers=args.layers, in_dim=64,
                    hidden_dim=args.hidden, num_classes=16,
                    num_relations=graph.num_edge_types)
    tc = TrainConfig(mode=args.mode, num_pes=args.pes, local_batch=args.batch,
                     num_steps=args.steps, fanout=args.fanout,
                     kappa=args.kappa, sampler=args.sampler,
                     partition=args.partition,
                     eval_every=max(args.steps // 5, 1))
    t0 = time.time()
    r = train_gnn(ds, cfg, tc)
    print(f"[{args.mode}] {args.steps} steps in {time.time()-t0:.1f}s  "
          f"loss {r.losses[0]:.3f}->{np.mean(r.losses[-5:]):.3f}  "
          f"val_f1={r.val_f1}")
    print(f"test_f1={evaluate(ds, cfg, r.params, tc, split='test'):.3f}")


def run_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.tokens import synthetic_token_batch
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_lm
    from repro.train.optim import adam_init

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    B, S = args.batch, args.seq
    s_text = S - cfg.num_prefix_tokens
    toks = synthetic_token_batch(B, s_text + 1, cfg.vocab_size, seed=0)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.num_prefix_tokens:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.num_prefix_tokens, cfg.d_model), cfg.jdtype)
    if cfg.enc_dec:
        batch["enc_out"] = jnp.zeros((B, cfg.enc_len, cfg.d_model), cfg.jdtype)
    t0 = time.time()
    for i in range(args.steps):
        params, opt, metrics = step(params, opt, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f}", flush=True)
    print(f"{args.steps} steps in {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("gnn")
    g.add_argument("--mode", default="cooperative",
                   choices=["cooperative", "independent"])
    g.add_argument("--model", default="gcn",
                   choices=["gcn", "sage", "gat", "rgcn"])
    g.add_argument("--pes", type=int, default=4)
    g.add_argument("--batch", type=int, default=64)
    g.add_argument("--steps", type=int, default=50)
    g.add_argument("--layers", type=int, default=3)
    g.add_argument("--hidden", type=int, default=128)
    g.add_argument("--fanout", type=int, default=10)
    g.add_argument("--kappa", type=int, default=1)
    g.add_argument("--sampler", default="labor0")
    g.add_argument("--partition", default="hash")
    g.add_argument("--scale", type=int, default=12)

    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--reduced", action="store_true")
    l.add_argument("--steps", type=int, default=3)
    l.add_argument("--batch", type=int, default=2)
    l.add_argument("--seq", type=int, default=64)

    args = ap.parse_args()
    if args.cmd == "gnn":
        run_gnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
