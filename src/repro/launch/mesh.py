"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ``data`` carries the batch (and is the PE axis for the paper's
    cooperative minibatching), ``model`` carries tensor parallelism,
    ``pod`` is the outer data-parallel axis across ICI islands (the
    paper's cooperation domain is one fast-interconnect island — see
    DESIGN.md §6 and paper §A.11).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(num_devices: int | None = None, axis: str = "data"):
    """Small 1-D mesh over available devices (tests, single-host runs)."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def make_coop_mesh(num_pes: int, axis_name: str = "data"):
    """1-D mesh carrying the cooperative PE axis (one PE per device).

    This is the mesh :class:`repro.engine.shard.ShardRunner` runs
    ``shard_map`` over.  On CPU, force a multi-device platform *before*
    importing jax::

        XLA_FLAGS=--xla_force_host_platform_device_count=P

    which is how CI exercises the real all-to-all path without TPUs.
    """
    avail = len(jax.devices())
    if avail < num_pes:
        raise ValueError(
            f"cooperative shard execution needs num_pes={num_pes} devices, "
            f"but jax sees {avail}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={num_pes} "
            f"before importing jax"
        )
    return jax.make_mesh((num_pes,), (axis_name,))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
