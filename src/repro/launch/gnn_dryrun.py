"""Dry-run of the paper's cooperative GNN training step on the mesh.

This is the production embodiment of Algorithm 1: every mesh device is a
PE; the graph is 1-D block-partitioned (each PE holds the in-CSR of its
vertex range plus its feature/label rows — owner-partitioned storage);
cooperative sampling, feature loading and forward/backward run inside
``shard_map`` with ``lax.all_to_all`` over the PE axis.  Multi-pod uses
an outer ``pod`` axis that data-parallelizes *independent global
batches* — cooperation stays inside a fast-ICI island per the paper's
own limitation analysis (§A.11, DESIGN.md §6).

Everything is ShapeDtypeStruct-lowered: papers100M-scale array shapes,
no allocation.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import frontier
from repro.core.cooperative import (
    CoopCapacityPlan,
    ShardExecutor,
    build_cooperative_minibatch,
    redistribute,
)
from repro.core.graph import INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers import LaborSampler
from repro.train.optim import adam_init, adam_update


# --------------------------------------------------------------------------
# block-local graph + partition (owner-partitioned storage)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LocalGraph:
    """Per-PE CSR block: rows are the PE's owned vertices.

    ``indices`` store GLOBAL source ids; ``v_start`` is the first owned
    vertex id, so local row = global id - v_start.  ``edge_types`` (R-GCN,
    mag240M) aligns with ``indices``.
    """

    indptr: jax.Array    # (Vp + 1,)
    indices: jax.Array   # (Ep,)
    v_start: jax.Array   # () int32
    max_degree: int
    edge_types: jax.Array | None = None  # (Ep,) relation ids

    def _row_window(self, seeds: jax.Array):
        Vp = self.indptr.shape[0] - 1
        Ep = self.indices.shape[0]
        local = jnp.where(seeds == INVALID, 0, seeds - self.v_start)
        local = jnp.clip(local, 0, Vp - 1)
        offs = self.indptr[local]
        deg = self.indptr[local + 1] - offs
        pos = jnp.arange(self.max_degree, dtype=jnp.int32)[None, :]
        idx = jnp.clip(offs[:, None] + pos, 0, max(Ep - 1, 0))
        mask = (pos < deg[:, None]) & (seeds != INVALID)[:, None]
        return idx, mask

    def neighbor_table(self, seeds: jax.Array):
        idx, mask = self._row_window(seeds)
        nbr = self.indices[idx]
        return jnp.where(mask, nbr, INVALID), mask

    def neighbor_edge_types(self, seeds: jax.Array):
        idx, mask = self._row_window(seeds)
        return jnp.where(mask, self.edge_types[idx], 0)


@dataclasses.dataclass(frozen=True)
class BlockPartition:
    """Functional owner map for contiguous blocks (no (V,) array)."""

    verts_per_pe: int
    num_parts: int

    def owner_of(self, ids: jax.Array) -> jax.Array:
        own = ids // jnp.int32(self.verts_per_pe)
        own = jnp.clip(own, 0, self.num_parts - 1)
        return jnp.where(ids == INVALID, self.num_parts - 1, own)


# --------------------------------------------------------------------------
# problem scales (Table 2) and models (A.5): papers100M/GCN, mag240M/R-GCN
# --------------------------------------------------------------------------
SCALE = dict(
    log2_v=27,          # 134M vertices (papers100M: 111M)
    avg_degree=29,      # papers100M: 29.1
    max_degree=32,      # degree-capped neighbor tables (DESIGN.md §3)
    feat_dim=128,       # papers100M feature dim
    hidden=1024,        # paper A.5
    classes=172,
    fanout=10,
    layers=3,
    local_batch=1024,   # b per PE; global batch = 1024 * P
    model="gcn",
    num_relations=1,
)

# mag240M / R-GCN (paper §4.3): heavier model M — the regime where the
# paper reports cooperation pays off even at P=2 (α/c > γ/M, Table 1).
SCALE_MAG = dict(
    log2_v=28,          # 268M vertices (mag240M: 244M)
    avg_degree=14,      # mag240M: 14.2
    max_degree=32,
    feat_dim=768,       # mag240M feature dim (fp16-stored in the paper)
    hidden=1024,
    classes=153,
    fanout=10,
    layers=3,
    local_batch=1024,
    model="rgcn",
    num_relations=4,    # author/paper/institution/field edge types
)


def _caps(P: int, bucket_safety: float = 3.0, scale: dict = None) -> CoopCapacityPlan:
    """Concavity-informed per-PE frontier capacities.

    Sized from the paper's measured cooperative per-PE frontier sizes on
    papers100M with LABOR-0, b=1024, k=10 (Table 7: |S^1|=9.3k,
    |S^2|=62k, |S^3|=318k, |S~^2|=83k, |S~^3|=463k) with ~30% headroom —
    the concave growth (Thm 3.2) is exactly why these are far below the
    geometric bound b·(k+1)^l.
    """
    scale = scale or SCALE
    assert scale["local_batch"] == 1024 and scale["fanout"] == 10
    caps = (1024, 12288, 81920, 417792)
    tilde = (16384, 106496, 606208)
    buckets = tuple(
        max(64, int(t // P * bucket_safety) // 8 * 8 + 8) for t in tilde
    )
    return CoopCapacityPlan(caps, tilde, buckets)


def _gnn_params_specs(scale: dict, dtype=jnp.float32):
    # plan layer l computes H^l from H^{l+1}: layer L-1 consumes raw
    # features, layer 0 emits class logits (models/gnn convention)
    L = scale["layers"]
    out = []
    for l in range(L):
        d_in = scale["feat_dim"] if l == L - 1 else scale["hidden"]
        d_out = scale["classes"] if l == 0 else scale["hidden"]
        lp = {
            "w": jax.ShapeDtypeStruct((d_in, d_out), dtype),
            "b": jax.ShapeDtypeStruct((d_out,), dtype),
        }
        if scale["model"] == "rgcn":
            lp["w_rel"] = jax.ShapeDtypeStruct(
                (scale["num_relations"], d_in, d_out), dtype
            )
        out.append(lp)
    return out


def _gcn_layer(p, Ht, self_idx, nbr_idx, mask, etypes, last: bool):
    h_self = Ht[jnp.clip(self_idx, 0)]
    h_nbr = Ht[jnp.clip(nbr_idx, 0)]
    valid = (nbr_idx >= 0) & mask
    h_nbr = jnp.where(valid[..., None], h_nbr, 0.0)
    deg = jnp.sum(valid, axis=-1, keepdims=True) + 1
    agg = (jnp.sum(h_nbr, axis=-2) + h_self) / deg
    out = agg @ p["w"] + p["b"]
    return out if last else jax.nn.relu(out)


def _rgcn_layer(p, Ht, self_idx, nbr_idx, mask, etypes, last: bool):
    """R-GCN (Schlichtkrull et al.): per-relation mean aggregation."""
    h_self = Ht[jnp.clip(self_idx, 0)]
    h_nbr = Ht[jnp.clip(nbr_idx, 0)]
    valid = (nbr_idx >= 0) & mask
    out = h_self @ p["w"] + p["b"]
    R = p["w_rel"].shape[0]
    et = etypes if etypes is not None else jnp.zeros(mask.shape, jnp.int32)
    for r in range(R):
        m_r = valid & (et == r)
        s = jnp.sum(jnp.where(m_r[..., None], h_nbr, 0.0), axis=-2)
        n = jnp.maximum(jnp.sum(m_r, axis=-1, keepdims=True), 1)
        out = out + (s / n) @ p["w_rel"][r]
    return out if last else jax.nn.relu(out)


def make_coop_train_step(P: int, pe_axes, caps: CoopCapacityPlan, grad_axes=None,
                         scale: dict = None):
    """Cooperative GNN train step body (runs per-PE inside shard_map)."""
    scale = scale or SCALE
    sampler = LaborSampler(fanout=scale["fanout"])
    part = BlockPartition((1 << scale["log2_v"]) // P, P)
    ex = ShardExecutor(P, axis_name=pe_axes)
    L = scale["layers"]
    grad_axes = grad_axes or pe_axes
    layer_fn = _rgcn_layer if scale["model"] == "rgcn" else _gcn_layer

    def step(params, opt, indptr, indices, v_start, feats, labels, seeds,
             rng_step, etypes=None):
        graph = LocalGraph(indptr, indices, v_start, scale["max_degree"],
                           edge_types=etypes)
        rng = DependentRNG(base_seed=0, kappa=64).state_at(rng_step)
        mb = build_cooperative_minibatch(
            graph, sampler, part, seeds, rng, L, caps, ex
        )

        def loss_fn(params):
            ids = mb.input_ids
            local = jnp.clip(
                jnp.where(ids == INVALID, 0, ids - v_start), 0, feats.shape[0] - 1
            )
            H = jnp.where(
                (ids != INVALID)[:, None], feats[local], 0.0
            )
            for l in reversed(range(L)):
                blk = mb.layers[l]
                Ht = redistribute(ex, blk, H, caps.tilde_caps[l])
                H = layer_fn(
                    params[l], Ht, blk.self_idx, blk.nbr_idx, blk.mask,
                    blk.etypes, last=(l == 0),
                )
            seed_ids = mb.seed_ids
            lab_local = jnp.clip(
                jnp.where(seed_ids == INVALID, 0, seed_ids - v_start),
                0,
                labels.shape[0] - 1,
            )
            y = labels[lab_local]
            valid = seed_ids != INVALID
            logits = H.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            n = jnp.maximum(jnp.sum(valid), 1)
            loss = jnp.sum(jnp.where(valid, logz - ll, 0.0)) / n
            return jax.lax.pmean(loss, pe_axes)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.lax.pmean(grads, grad_axes)
        params, opt = adam_update(params, grads, opt, lr=1e-3)
        return params, opt, loss

    return step


def lower_gnn_coop_step(
    multi_pod: bool = False,
    verbose: bool = True,
    feat_dtype: str = "float32",
    bucket_safety: float = 3.0,
    model: str = "gcn",
    tag: str = "",
) -> dict:
    from jax.experimental.shard_map import shard_map

    from repro.launch import roofline as rl

    scale = SCALE_MAG if model == "rgcn" else SCALE
    NPE = 256
    pods = 2 if multi_pod else 1
    mesh = jax.make_mesh((pods, NPE), ("pod", "pe"))
    V = 1 << scale["log2_v"]
    vp = V // NPE
    ep = vp * scale["avg_degree"]
    caps = _caps(NPE, bucket_safety=bucket_safety, scale=scale)
    fdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[feat_dtype]
    grad_axes = ("pe", "pod") if multi_pod else ("pe",)
    step = make_coop_train_step(NPE, "pe", caps, grad_axes=grad_axes, scale=scale)
    rgcn = scale["model"] == "rgcn"

    params_s = _gnn_params_specs(scale)
    opt_s = jax.eval_shape(lambda p: adam_init(p), params_s)
    specs = dict(
        indptr=jax.ShapeDtypeStruct((pods, NPE * (vp + 1)), jnp.int32),
        indices=jax.ShapeDtypeStruct((pods, NPE * ep), jnp.int32),
        v_start=jax.ShapeDtypeStruct((pods, NPE), jnp.int32),
        feats=jax.ShapeDtypeStruct((pods, V, scale["feat_dim"]), fdt),
        labels=jax.ShapeDtypeStruct((pods, V), jnp.int32),
        seeds=jax.ShapeDtypeStruct((pods, NPE, scale["local_batch"]), jnp.int32),
        etypes=jax.ShapeDtypeStruct((pods, NPE * ep), jnp.int32),
    )

    def sharded_step(params, opt, indptr, indices, v_start, feats, labels,
                     seeds, etypes):
        def per_pe(params, opt, indptr, indices, v_start, feats, labels,
                   seeds, etypes):
            return step(
                params,
                opt,
                indptr.reshape(-1)[: vp + 1],
                indices.reshape(-1),
                v_start.reshape(-1)[0],
                feats.reshape(-1, scale["feat_dim"]),
                labels.reshape(-1),
                seeds.reshape(-1),
                jnp.int32(0),
                etypes.reshape(-1) if rgcn else None,
            )

        return shard_map(
            per_pe,
            mesh=mesh,
            in_specs=(
                P(),                    # params replicated
                P(),                    # opt replicated
                P("pod", "pe"),
                P("pod", "pe"),
                P("pod", "pe"),
                P("pod", ("pe",)),      # feats: rows owner-partitioned
                P("pod", ("pe",)),
                P("pod", "pe", None),
                P("pod", "pe"),         # etypes (aligned with indices)
            ),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, opt, indptr, indices, v_start, feats, labels, seeds, etypes)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(sharded_step).lower(
            params_s,
            opt_s,
            specs["indptr"],
            specs["indices"],
            specs["v_start"],
            specs["feats"],
            specs["labels"],
            specs["seeds"],
            specs["etypes"],
        )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    model_flops = 0.0  # GNN: flops are data-dependent; report HLO terms only
    roof = rl.analyze(compiled, mesh.size, model_flops)
    result = {
        "arch": "gnn-coop-mag240M-rgcn" if rgcn else "gnn-coop-papers100M-gcn",
        "shape": f"b{scale['local_batch']}xP{NPE}",
        "mesh": "pod2x256" if multi_pod else "pod1x256",
        "tag": tag,
        "overrides": {"feat_dtype": feat_dtype, "bucket_safety": bucket_safety,
                      "model": scale["model"]},
        "status": "ok",
        "devices": mesh.size,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "peak_per_device_gb": roof.peak_mem_bytes / 2**30,
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(
            f"[{result['arch']} | {result['shape']} | {result['mesh']}] ok "
            f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
            f"peak/dev {result['memory']['peak_per_device_gb']:.2f} GiB "
            f"bottleneck={roof.bottleneck} "
            f"(c={roof.compute_s*1e3:.2f}ms m={roof.memory_s*1e3:.2f}ms "
            f"coll={roof.collective_s*1e3:.2f}ms)",
            flush=True,
        )
    return result
