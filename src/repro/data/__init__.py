from repro.data.synthetic import SyntheticGraphDataset, rmat_graph
from repro.data.tokens import synthetic_token_batch

__all__ = ["SyntheticGraphDataset", "rmat_graph", "synthetic_token_batch"]
