from repro.data.recsys import RecsysDataset, make_recsys, recsys_graph
from repro.data.synthetic import SyntheticGraphDataset, rmat_graph
from repro.data.tokens import synthetic_token_batch

__all__ = [
    "RecsysDataset",
    "SyntheticGraphDataset",
    "make_recsys",
    "recsys_graph",
    "rmat_graph",
    "synthetic_token_batch",
]
