"""Synthetic bipartite user-item recommendation graph (serving workload).

The ROADMAP's "millions of users" scenario made concrete: ``U`` users and
``I`` items with power-law degrees on *both* sides — user activity is
Pareto-distributed (a few heavy users, a long tail of light ones) and
item popularity is Zipfian (a small head of hot items absorbs most
edges).  Concurrent users' ego-networks therefore overlap heavily in the
hot-item head, which is exactly the concavity condition (Thm 3.2) that
makes coalesced inference serving fetch strictly less than per-request
execution (``repro.serve``).

Vertex layout: users occupy ids ``[0, U)``, items ``[U, U + I)``.  The
graph is undirected (edges in both CSR directions) so a 2-layer ego
query from a user reaches items and co-consuming users.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    """p(rank r) ∝ (r+1)^-alpha, normalized."""
    p = (np.arange(1, n + 1, dtype=np.float64)) ** (-alpha)
    return p / p.sum()


def recsys_graph(
    num_users: int = 4096,
    num_items: int = 1024,
    edges_per_user: float = 8.0,
    item_alpha: float = 1.05,
    user_pareto: float = 2.5,
    max_degree: int = 64,
    seed: int = 0,
) -> Graph:
    """Bipartite user-item interaction graph with power-law degrees.

    ``edges_per_user`` sets the *mean* user activity; per-user counts are
    Pareto(``user_pareto``) draws scaled to that mean.  Each interaction
    picks an item from a Zipf(``item_alpha``) popularity ranking over a
    seed-deterministic item permutation, so hot items are not simply the
    low ids.  Degrees are capped at ``max_degree`` (down-sampled) like
    every other graph in the repo so sampling lowers with static shapes.
    """
    rng = np.random.default_rng(seed)
    U, I = num_users, num_items
    # user activity: Pareto with mean scaled to edges_per_user, >= 1
    raw = rng.pareto(user_pareto, U) + 1.0
    k_u = np.maximum(1, np.round(raw * (edges_per_user / raw.mean()))).astype(
        np.int64
    )
    src_users = np.repeat(np.arange(U, dtype=np.int64), k_u)
    # item popularity: Zipf over a hidden ranking permutation
    ranked = rng.permutation(I)
    items = ranked[
        rng.choice(I, size=len(src_users), p=_zipf_probs(I, item_alpha))
    ]
    dst_items = items.astype(np.int64) + U
    # dedup repeat (user, item) interactions
    key = src_users * (U + I) + dst_items
    _, uniq = np.unique(key, return_index=True)
    src_users, dst_items = src_users[uniq], dst_items[uniq]
    src = np.concatenate([src_users, dst_items])
    dst = np.concatenate([dst_items, src_users])
    return Graph.from_edges(
        src, dst, num_vertices=U + I, max_degree=max_degree, seed=seed
    )


@dataclass
class RecsysDataset:
    """Bipartite graph + feature rows + the user-id query population.

    Mirrors :class:`repro.data.synthetic.SyntheticGraphDataset`'s surface
    where the engine needs it (``features``, ``train_ids``) so a
    ``MinibatchEngine`` can be constructed directly over it; serving
    treats ``user_ids`` as the population live queries draw seeds from.
    """

    graph: Graph
    num_users: int
    feature_dim: int = 64
    num_classes: int = 16
    seed: int = 0
    features: jax.Array = field(init=False)
    user_ids: np.ndarray = field(init=False)
    item_ids: np.ndarray = field(init=False)
    train_ids: np.ndarray = field(init=False)

    def __post_init__(self):
        V = self.graph.num_vertices
        if not 0 < self.num_users < V:
            raise ValueError(
                f"num_users must be in (0, {V}), got {self.num_users}"
            )
        rng = np.random.default_rng(self.seed)
        feats = rng.standard_normal((V, self.feature_dim)).astype(np.float32)
        self.features = jnp.asarray(feats)
        self.user_ids = np.arange(self.num_users, dtype=np.int32)
        self.item_ids = np.arange(self.num_users, V, dtype=np.int32)
        self.train_ids = self.user_ids

    @property
    def num_items(self) -> int:
        return self.graph.num_vertices - self.num_users


def make_recsys(
    num_users: int = 4096,
    num_items: int = 1024,
    edges_per_user: float = 8.0,
    feature_dim: int = 64,
    num_classes: int = 16,
    max_degree: int = 64,
    seed: int = 0,
) -> RecsysDataset:
    """One-call workload constructor used by serving benchmarks/examples."""
    g = recsys_graph(
        num_users=num_users,
        num_items=num_items,
        edges_per_user=edges_per_user,
        max_degree=max_degree,
        seed=seed,
    )
    return RecsysDataset(
        g, num_users=num_users, feature_dim=feature_dim,
        num_classes=num_classes, seed=seed,
    )
