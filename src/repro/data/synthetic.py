"""Synthetic power-law graph datasets (RMAT) + features/labels.

The container is offline, so we substitute the paper's datasets
(reddit/yelp/flickr/papers100M/mag240M) with degree-capped RMAT graphs
whose *shape statistics* (power-law degrees, small diameter, avg degree)
drive the theorems — Thm 3.1/3.2/3.3 hold for every graph, so synthetic
graphs validate the claims qualitatively (DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


def rmat_edges(
    scale: int,
    edge_factor: int,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Classic RMAT generator: 2**scale vertices, edge_factor*V edges."""
    rng = np.random.default_rng(seed)
    V = 1 << scale
    E = edge_factor * V
    src = np.zeros(E, dtype=np.int64)
    dst = np.zeros(E, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(E)
        go_src = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        go_dst = (r >= a) & (r < a + b) | (r >= a + b + c)
        src |= go_src.astype(np.int64) << bit
        dst |= go_dst.astype(np.int64) << bit
    # permute ids to break the RMAT bit-prefix locality a little (but keep
    # some, so the BFS partitioner has structure to exploit)
    keep = src != dst  # drop self loops
    return src[keep], dst[keep]


def rmat_graph(
    scale: int = 12,
    edge_factor: int = 8,
    max_degree: int = 64,
    undirected: bool = True,
    num_edge_types: int = 1,
    seed: int = 0,
) -> Graph:
    src, dst = rmat_edges(scale, edge_factor, seed=seed)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    # dedup parallel edges
    key = src * (1 << scale) + dst
    _, uniq_idx = np.unique(key, return_index=True)
    src, dst = src[uniq_idx], dst[uniq_idx]
    et = None
    if num_edge_types > 1:
        rng = np.random.default_rng(seed + 1)
        et = rng.integers(0, num_edge_types, size=len(src)).astype(np.int32)
    return Graph.from_edges(
        src,
        dst,
        num_vertices=1 << scale,
        edge_types=et,
        max_degree=max_degree,
        num_edge_types=num_edge_types,
        seed=seed,
    )


@dataclass
class SyntheticGraphDataset:
    """Graph + node features + labels + train/val/test split.

    Features are a fixed random projection of the vertex id (deterministic,
    storable "on disk" conceptually) and labels come from a hidden 2-layer
    propagation so that a GNN can actually fit them (non-trivial
    convergence experiments, Fig 4/9).
    """

    graph: Graph
    feature_dim: int = 64
    num_classes: int = 16
    seed: int = 0
    features: jax.Array = field(init=False)
    labels: jax.Array = field(init=False)
    train_ids: np.ndarray = field(init=False)
    val_ids: np.ndarray = field(init=False)
    test_ids: np.ndarray = field(init=False)

    def __post_init__(self):
        V = self.graph.num_vertices
        rng = np.random.default_rng(self.seed)
        feats = rng.standard_normal((V, self.feature_dim)).astype(np.float32)
        self.features = jnp.asarray(feats)
        # hidden teacher: labels depend on own + 1-hop-mean features
        W = rng.standard_normal((self.feature_dim, self.num_classes)).astype(
            np.float32
        )
        indptr = np.asarray(self.graph.indptr)
        indices = np.asarray(self.graph.indices)
        deg = np.maximum(np.diff(indptr), 1)
        agg = np.zeros_like(feats)
        np.add.at(agg, np.repeat(np.arange(V), np.diff(indptr)), feats[indices])
        agg /= deg[:, None]
        logits = (feats + agg) @ W
        self.labels = jnp.asarray(np.argmax(logits, axis=1).astype(np.int32))
        perm = rng.permutation(V)
        n_tr, n_val = int(0.6 * V), int(0.2 * V)
        self.train_ids = np.sort(perm[:n_tr]).astype(np.int32)
        self.val_ids = np.sort(perm[n_tr : n_tr + n_val]).astype(np.int32)
        self.test_ids = np.sort(perm[n_tr + n_val :]).astype(np.int32)

    def seed_batch(self, step: int, batch_size: int, split: str = "train") -> np.ndarray:
        """Deterministic epoch-shuffled seed-vertex batch (host-side)."""
        ids = {"train": self.train_ids, "val": self.val_ids, "test": self.test_ids}[
            split
        ]
        n = len(ids)
        per_epoch = max(1, n // batch_size)
        epoch, it = divmod(step, per_epoch)
        order = np.random.default_rng(self.seed + 17 * epoch).permutation(n)
        sel = order[it * batch_size : (it + 1) * batch_size]
        return ids[sel]
