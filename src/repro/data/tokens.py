"""Synthetic token streams for the LM architecture pool.

Zipf-distributed token ids (matching natural-language frequency shape)
so that the cooperative-embedding-gather transfer of the paper's idea
(DESIGN.md §4) sees realistic duplicate rates.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_batch(
    batch: int, seq: int, vocab: int, seed: int = 0, zipf_a: float = 1.1
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # rejection-free bounded zipf via inverse-CDF over a truncated support
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    return rng.choice(vocab, size=(batch, seq), p=probs).astype(np.int32)
