"""CSR graph container in JAX device arrays.

The graph stores *incoming* edges in CSR form: for vertex ``s`` the
in-neighborhood ``N(s) = {t | (t -> s) in E}`` lives at
``indices[indptr[s] : indptr[s+1]]`` — matching the paper's message
direction (embeddings flow t -> s, eq. (1)).

TPU adaptation note: all sampling paths operate on *degree-capped*
neighbor tables of static shape ``(num_seeds, max_degree)`` so that every
hop lowers with static shapes (see DESIGN.md §3).  The synthetic data
generator caps degrees; for external graphs ``Graph.from_edges`` can
optionally down-sample over-capacity neighborhoods.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

INVALID = np.int32(np.iinfo(np.int32).max)  # padding sentinel for vertex ids


class GraphValidationError(ValueError):
    """A CSR graph failed well-formedness checks (see Graph.validate)."""

    def __init__(self, problems: list):
        self.problems = list(problems)
        super().__init__(
            "malformed CSR graph: " + "; ".join(self.problems)
        )


@dataclass(frozen=True)
class Graph:
    """Static-shape CSR graph of in-edges.

    Attributes:
      indptr:  (V+1,) int32 row pointer over destination vertices.
      indices: (E,)   int32 source vertex of each in-edge.
      edge_types: optional (E,) int32 relation ids (R-GCN).
      max_degree: static python int — max in-degree (after capping).
    """

    indptr: jax.Array
    indices: jax.Array
    edge_types: Optional[jax.Array]
    max_degree: int
    num_vertices: int
    num_edges: int
    num_edge_types: int

    # mark statics as pytree metadata
    __static_fields__ = ("max_degree", "num_vertices", "num_edges", "num_edge_types")

    @property
    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def validate(self) -> "Graph":
        """Check CSR well-formedness; raise GraphValidationError if broken.

        Verifies: int32 dtypes, ``indptr`` shape ``(V+1,)`` with
        ``indptr[0] == 0`` and ``indptr[-1] == num_edges``, monotone
        non-decreasing ``indptr``, in-range ``indices``, per-row degrees
        within ``max_degree``, and ``edge_types`` alignment.  Costs one
        O(V+E) device reduction plus a host sync, so call it at
        construction boundaries (``MinibatchEngine.from_config`` does),
        never per step.  Returns ``self`` for chaining.
        """
        problems = []
        V, E = self.num_vertices, self.num_edges
        if self.indptr.dtype != jnp.int32:
            problems.append(f"indptr dtype {self.indptr.dtype} != int32")
        if self.indices.dtype != jnp.int32:
            problems.append(f"indices dtype {self.indices.dtype} != int32")
        if self.indptr.shape != (V + 1,):
            problems.append(
                f"indptr shape {self.indptr.shape} != ({V + 1},) "
                f"for num_vertices={V}"
            )
        if self.indices.shape != (E,):
            problems.append(
                f"indices shape {self.indices.shape} != ({E},) "
                f"for num_edges={E}"
            )
        if self.edge_types is not None and self.edge_types.shape != (E,):
            problems.append(
                f"edge_types shape {self.edge_types.shape} != ({E},)"
            )
        if problems:  # shape/dtype errors make the value checks undefined
            raise GraphValidationError(problems)

        first = int(self.indptr[0])
        last = int(self.indptr[-1])
        if first != 0:
            problems.append(f"indptr[0] == {first} != 0")
        if last != E:
            problems.append(f"indptr[-1] == {last} != num_edges ({E})")
        deg = self.degrees
        n_nonmono = int(jnp.sum(deg < 0))
        if n_nonmono:
            problems.append(
                f"indptr not monotone non-decreasing at {n_nonmono} row(s)"
            )
        elif int(jnp.max(deg, initial=0)) > self.max_degree:
            problems.append(
                f"max in-degree {int(jnp.max(deg, initial=0))} exceeds "
                f"declared max_degree={self.max_degree}"
            )
        if E:
            n_oob = int(jnp.sum((self.indices < 0) | (self.indices >= V)))
            if n_oob:
                problems.append(
                    f"{n_oob} edge indices outside [0, {V})"
                )
        if self.edge_types is not None and E:
            n_bad_et = int(jnp.sum(
                (self.edge_types < 0)
                | (self.edge_types >= self.num_edge_types)
            ))
            if n_bad_et:
                problems.append(
                    f"{n_bad_et} edge types outside "
                    f"[0, {self.num_edge_types})"
                )
        if problems:
            raise GraphValidationError(problems)
        return self

    @staticmethod
    def from_edges(
        src: np.ndarray,
        dst: np.ndarray,
        num_vertices: int,
        edge_types: Optional[np.ndarray] = None,
        max_degree: Optional[int] = None,
        num_edge_types: int = 1,
        seed: int = 0,
    ) -> "Graph":
        """Build an in-CSR graph from a (t -> s) edge list; host-side."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        if edge_types is not None:
            edge_types = np.asarray(edge_types)[order]
        counts = np.bincount(dst, minlength=num_vertices)
        cap = int(max_degree) if max_degree is not None else int(counts.max(initial=0))
        if counts.max(initial=0) > cap:
            # Down-sample over-capacity neighborhoods (documented adaptation).
            rng = np.random.default_rng(seed)
            keep = np.ones(len(src), dtype=bool)
            indptr_full = np.zeros(num_vertices + 1, dtype=np.int64)
            np.cumsum(counts, out=indptr_full[1:])
            for v in np.nonzero(counts > cap)[0]:
                sl = slice(indptr_full[v], indptr_full[v + 1])
                drop = rng.choice(counts[v], size=counts[v] - cap, replace=False)
                keep_v = np.ones(counts[v], dtype=bool)
                keep_v[drop] = False
                keep[sl] = keep_v
            src, dst = src[keep], dst[keep]
            if edge_types is not None:
                edge_types = edge_types[keep]
            counts = np.bincount(dst, minlength=num_vertices)
        indptr = np.zeros(num_vertices + 1, dtype=np.int32)
        np.cumsum(counts, out=indptr[1:])
        return Graph(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(src, jnp.int32),
            edge_types=None
            if edge_types is None
            else jnp.asarray(edge_types, jnp.int32),
            max_degree=int(min(cap, counts.max(initial=0))) or 1,
            num_vertices=int(num_vertices),
            num_edges=int(len(src)),
            num_edge_types=int(num_edge_types),
        )

    def neighbor_table(
        self, seeds: jax.Array, backend: str = "reference"
    ) -> tuple[jax.Array, jax.Array]:
        """Gather the (padded) in-neighborhoods of ``seeds``.

        Args:
          seeds: (n,) int32 vertex ids, INVALID-padded.
          backend: "reference" (jnp gather) or "fused" (the paged
            :mod:`repro.kernels.frontier_gather` Pallas sweep on TPU) —
            bit-identical outputs.
        Returns:
          nbr:  (n, max_degree) int32 source ids, INVALID where padded.
          mask: (n, max_degree) bool validity.
        """
        if backend == "fused":
            from repro import kernels

            return kernels.frontier_gather(
                self.indptr, self.indices, seeds, self.max_degree
            )
        return _neighbor_table(self.indptr, self.indices, seeds, self.max_degree)

    def neighbor_edge_types(self, seeds: jax.Array) -> jax.Array:
        """(n, max_degree) int32 relation ids aligned with neighbor_table."""
        assert self.edge_types is not None
        safe = jnp.where(seeds == INVALID, 0, seeds)
        offs = self.indptr[safe]
        deg = self.indptr[safe + 1] - offs
        pos = jnp.arange(self.max_degree, dtype=jnp.int32)[None, :]
        idx = jnp.clip(offs[:, None] + pos, 0, self.num_edges - 1)
        et = self.edge_types[idx]
        valid = (pos < deg[:, None]) & (seeds != INVALID)[:, None]
        return jnp.where(valid, et, 0)


# pytree registration with static metadata ---------------------------------

def _graph_flatten(g: Graph):
    children = (g.indptr, g.indices, g.edge_types)
    aux = (g.max_degree, g.num_vertices, g.num_edges, g.num_edge_types)
    return children, aux


def _graph_unflatten(aux, children):
    indptr, indices, edge_types = children
    return Graph(indptr, indices, edge_types, *aux)


jax.tree_util.register_pytree_node(Graph, _graph_flatten, _graph_unflatten)


@partial(jax.jit, static_argnums=(3,))
def _neighbor_table(indptr, indices, seeds, max_degree):
    num_edges = indices.shape[0]
    safe = jnp.where(seeds == INVALID, 0, seeds)
    offs = indptr[safe]
    deg = indptr[safe + 1] - offs
    pos = jnp.arange(max_degree, dtype=jnp.int32)[None, :]
    idx = jnp.clip(offs[:, None] + pos, 0, max(num_edges - 1, 0))
    nbr = indices[idx]
    mask = (pos < deg[:, None]) & (seeds != INVALID)[:, None]
    nbr = jnp.where(mask, nbr, INVALID)
    return nbr, mask
