"""Empirical validation harness for Theorems 3.1 / 3.2 / 3.3.

Measures ``E[|S^l|]`` over random seed batches as a function of batch
size and checks:

* work monotonicity  — E[|S^l|]/|S^0| nonincreasing in |S^0| (Thm 3.1),
* concavity          — discrete second differences of E[|S^l|] <= 0
                       (Thm 3.2, up to sampling noise),
* density            — E[|S_E|]/|S| of the vertex-induced subgraph is
                       nondecreasing in |S| (Thm 3.3).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.graph import Graph, INVALID
from repro.core.minibatch import CapacityPlan, build_minibatch
from repro.core.rng import DependentRNG
from repro.core.samplers.base import Sampler


@dataclass
class WorkCurve:
    batch_sizes: list[int]
    expected_sl: list[float]     # E[|S^L|]
    work_per_seed: list[float]   # E[|S^L|] / |S^0|


def measure_work_curve(
    graph: Graph,
    sampler: Sampler,
    batch_sizes: list[int],
    num_layers: int = 3,
    trials: int = 8,
    seed: int = 0,
    fanout_for_caps: int = 10,
) -> WorkCurve:
    rng_np = np.random.default_rng(seed)
    e_sl, wps = [], []
    for bs in batch_sizes:
        caps = CapacityPlan.geometric(
            bs, num_layers, fanout_for_caps, graph.num_vertices
        )
        sizes = []
        for t in range(trials):
            seeds = rng_np.choice(graph.num_vertices, size=bs, replace=False)
            rng = DependentRNG(base_seed=seed + 101 * t, kappa=1, step=0)
            mb = build_minibatch(
                graph, sampler, jnp.asarray(seeds, jnp.int32), rng, num_layers, caps
            )
            sizes.append(int(mb.num_inputs))
        e = float(np.mean(sizes))
        e_sl.append(e)
        wps.append(e / bs)
    return WorkCurve(list(batch_sizes), e_sl, wps)


def is_monotone_nonincreasing(xs: list[float], tol: float = 0.03) -> bool:
    """Allow `tol` relative sampling noise between consecutive points."""
    return all(b <= a * (1 + tol) for a, b in zip(xs, xs[1:]))


def is_concave(batch_sizes: list[int], values: list[float], tol: float = 0.05) -> bool:
    """Discrete concavity check on (possibly non-uniform) grid."""
    slopes = [
        (v2 - v1) / (b2 - b1)
        for (b1, v1), (b2, v2) in zip(
            zip(batch_sizes, values), zip(batch_sizes[1:], values[1:])
        )
    ]
    scale = max(abs(s) for s in slopes) + 1e-9
    return all(s2 <= s1 + tol * scale for s1, s2 in zip(slopes, slopes[1:]))


def measure_density_curve(
    graph: Graph, batch_sizes: list[int], trials: int = 8, seed: int = 0
) -> tuple[list[int], list[float]]:
    """Subgraph-sampling density E[|S_E|]/|S| (Thm 3.3 setting).

    Vertex-induced subgraph over a uniform vertex subset S.
    """
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    dst = np.repeat(np.arange(graph.num_vertices), np.diff(indptr))
    rng = np.random.default_rng(seed)
    density = []
    for bs in batch_sizes:
        vals = []
        for _ in range(trials):
            S = rng.choice(graph.num_vertices, size=bs, replace=False)
            mask = np.zeros(graph.num_vertices, bool)
            mask[S] = True
            e = int((mask[indices] & mask[dst]).sum())
            vals.append(e / bs)
        density.append(float(np.mean(vals)))
    return list(batch_sizes), density


def unique_vertex_fraction(mb_input_ids, per_pe: bool) -> float:
    """|T^l|-style overlap diagnostic: fraction of inputs touched once."""
    ids = np.asarray(mb_input_ids).ravel()
    ids = ids[ids != INVALID]
    _, counts = np.unique(ids, return_counts=True)
    return float((counts == 1).sum() / max(1, len(counts)))
