"""LRU vertex-embedding cache simulator (§4.2, Fig. 5).

The paper demonstrates dependent minibatching by measuring LRU-cache miss
rates for vertex-embedding fetches (miss rate ∝ storage-to-PE traffic).
True LRU is host-side control flow, so on TPU we model the *hit rate*
with an exact simulator (numpy, ordered dict) — this is the oracle the
Fig. 5 / Table 6 benchmarks use — and provide a batched variant for
multi-PE (cooperative) caching where each PE caches only owned vertices,
which is what makes cooperative feature loading "effectively increase the
global cache size" (§4.3.1).
"""
from __future__ import annotations

import bisect
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

_INVALID = np.iinfo(np.int32).max


@dataclass
class LRUCache:
    """Exact LRU over vertex ids; counts unique-per-batch accesses."""

    capacity: int
    hits: int = 0
    misses: int = 0
    _store: OrderedDict = field(default_factory=OrderedDict)
    # primary fast-path state: LRU-ordered key array, oldest first.
    # ``_store`` is only materialized for the sequential fallback;
    # ``_store_stale`` marks it behind ``_keys``.
    _keys: np.ndarray = field(default=None, repr=False)
    _store_stale: bool = field(default=False, repr=False)

    def access_batch(self, ids: np.ndarray) -> int:
        """Access the unique valid ids of one minibatch; returns #misses.

        Equivalent to processing the sorted unique ids one at a time
        (hit -> move to end; miss -> insert, evict LRU front), but run as
        a vectorized membership precheck — one ``searchsorted`` of the
        LRU-ordered key array into the (sorted-unique) batch — plus bulk
        array surgery, so oracle replays on large traces are not
        dominated by the per-element Python loop.

        The only subtlety is a cached key that is both in the batch and
        within eviction reach: whether it is re-hit or evicted-then-
        re-missed depends on the interleaving of its access with the
        eviction stream.  Because evictions consume original-key
        positions front-to-back (hits leave the front region; with
        ``n <= capacity`` reinserted keys are never re-evicted), each
        such *at-risk* key is resolved exactly, in access order: it is
        evicted iff the evictions issued before its access
        (``misses_so_far - free_slack``) cover every consumable position
        ahead of it plus itself.  Only batches larger than the capacity
        fall back to the sequential walk.
        """
        ids = np.unique(np.asarray(ids).ravel().astype(np.int64))
        ids = ids[ids != _INVALID]
        n = len(ids)
        if n == 0:
            return 0
        if n > self.capacity:
            # evictions can reach keys reinserted mid-batch; rare — the
            # whole cache turns over — so exactness beats speed here
            return self._access_sequential(ids)
        if self._keys is None:
            self._keys = np.fromiter(
                self._store.keys(), dtype=np.int64, count=len(self._store)
            )
        keys = self._keys  # LRU order, oldest first
        m0 = len(keys)
        pos = np.searchsorted(ids, keys)
        touched = np.zeros(m0, bool)
        inb = pos < n
        touched[inb] = ids[pos[inb]] == keys[inb]
        member = np.zeros(n, bool)  # batch ranks present in the cache
        member[pos[touched]] = True
        base_miss = n - int(touched.sum())  # misses ignoring evictions
        # base_cum[r] = definite misses among ids[:r]
        base_cum = np.concatenate(([0], np.cumsum(~member)))
        slack = self.capacity - m0
        tp = np.flatnonzero(touched)  # touched positions, oldest first
        # Eviction-frontier upper bound F: the frontier passes f
        # positions after E evictions and S skips (f = E + S), with
        # E <= max(0, m0 + base_miss + X - capacity) and X + S =
        # touched-below-f.  So any reachable f satisfies
        # f <= g(f) = max(0, base_miss - slack + #touched<f); g grows by
        # <= 1 per position, so {f : f <= g(f)} is an interval [0, F] —
        # find F by binary search.  Touched keys at positions >= F are
        # certain hits.
        lo, hi = 0, m0
        while lo < hi:
            mid = (lo + hi + 1) // 2
            bound = base_miss - slack + int(np.searchsorted(tp, mid))
            if mid <= max(0, bound):
                lo = mid
            else:
                hi = mid - 1
        n_risk = int(np.searchsorted(tp, lo))  # at-risk = tp[:n_risk]
        extra = 0  # evicted-then-re-missed at-risk keys so far
        evict_pos: list = []  # their positions, sorted
        if n_risk:
            ar = tp[:n_risk]
            ar_ranks = pos[ar]
            proc: list = []  # processed at-risk positions, sorted
            for oi in np.argsort(ar_ranks).tolist():
                q = int(ar[oi])
                # evictions issued before this key's access vs the
                # consumable positions the frontier must pass first:
                # every position < q except touched keys hit before the
                # frontier reached them
                issued = int(base_cum[ar_ranks[oi]]) + extra - slack
                avail = (
                    q
                    - bisect.bisect_left(proc, q)
                    + bisect.bisect_left(evict_pos, q)
                )
                if issued >= avail + 1:
                    extra += 1
                    bisect.insort(evict_pos, q)
                bisect.insort(proc, q)
        n_miss = base_miss + extra
        n_evict = max(0, m0 + n_miss - self.capacity)
        # victims: the first n_evict candidate positions (untouched or
        # evicted-at-risk); survivors keep relative order; batch ids land
        # at the end in ascending order, same as the sequential walk over
        # sorted unique ids
        keep = ~touched
        if n_evict:
            cand = keep.copy()
            if evict_pos:
                cand[evict_pos] = True
            keep[np.flatnonzero(cand)[:n_evict]] = False
        self._keys = np.concatenate([keys[keep], ids])
        self._store_stale = True
        self.hits += n - n_miss
        self.misses += n_miss
        return n_miss

    def _access_sequential(self, ids: np.ndarray) -> int:
        """Exact reference walk (sorted unique valid ids pre-applied)."""
        if self._store_stale:
            self._store = OrderedDict.fromkeys(self._keys.tolist(), True)
            self._store_stale = False
        miss_now = 0
        for v in ids.tolist():
            if v in self._store:
                self._store.move_to_end(v)
                self.hits += 1
            else:
                miss_now += 1
                self.misses += 1
                self._store[v] = True
                if len(self._store) > self.capacity:
                    self._store.popitem(last=False)
        self._keys = None  # the sequential walk reorders arbitrarily
        return miss_now

    def lru_keys(self) -> np.ndarray:
        """Resident keys in LRU order, oldest first (copy)."""
        if self._keys is None:
            self._keys = np.fromiter(
                self._store.keys(), dtype=np.int64, count=len(self._store)
            )
        return self._keys.copy()

    @property
    def miss_rate(self) -> float:
        total = self.hits + self.misses
        return self.misses / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = 0


@dataclass
class CooperativeCacheArray:
    """P per-PE LRU caches over *owned* ids (Fig. 5b setup).

    Independent minibatching: every PE caches any vertex it touches, so
    hot vertices occupy P cache slots globally.  Cooperative: vertices
    are fetched only by their owner, so the global effective capacity is
    P * capacity with zero duplication.
    """

    num_pes: int
    capacity_per_pe: int
    caches: list = field(default_factory=list)

    def __post_init__(self):
        if not self.caches:
            self.caches = [LRUCache(self.capacity_per_pe) for _ in range(self.num_pes)]

    def access(self, per_pe_ids: np.ndarray) -> int:
        """per_pe_ids: (P, n) padded id batches; returns total misses."""
        return sum(
            self.caches[p].access_batch(per_pe_ids[p]) for p in range(self.num_pes)
        )

    @property
    def miss_rate(self) -> float:
        h = sum(c.hits for c in self.caches)
        m = sum(c.misses for c in self.caches)
        return m / (h + m) if (h + m) else 0.0

    def reset_stats(self) -> None:
        for c in self.caches:
            c.reset_stats()
