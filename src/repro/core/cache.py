"""LRU vertex-embedding cache simulator (§4.2, Fig. 5).

The paper demonstrates dependent minibatching by measuring LRU-cache miss
rates for vertex-embedding fetches (miss rate ∝ storage-to-PE traffic).
True LRU is host-side control flow, so on TPU we model the *hit rate*
with an exact simulator (numpy, ordered dict) — this is the oracle the
Fig. 5 / Table 6 benchmarks use — and provide a batched variant for
multi-PE (cooperative) caching where each PE caches only owned vertices,
which is what makes cooperative feature loading "effectively increase the
global cache size" (§4.3.1).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

_INVALID = np.iinfo(np.int32).max


@dataclass
class LRUCache:
    """Exact LRU over vertex ids; counts unique-per-batch accesses."""

    capacity: int
    hits: int = 0
    misses: int = 0
    _store: OrderedDict = field(default_factory=OrderedDict)

    def access_batch(self, ids: np.ndarray) -> int:
        """Access the unique valid ids of one minibatch; returns #misses."""
        ids = np.unique(np.asarray(ids).ravel())
        ids = ids[ids != _INVALID]
        miss_now = 0
        for v in ids.tolist():
            if v in self._store:
                self._store.move_to_end(v)
                self.hits += 1
            else:
                miss_now += 1
                self.misses += 1
                self._store[v] = True
                if len(self._store) > self.capacity:
                    self._store.popitem(last=False)
        return miss_now

    @property
    def miss_rate(self) -> float:
        total = self.hits + self.misses
        return self.misses / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = 0


@dataclass
class CooperativeCacheArray:
    """P per-PE LRU caches over *owned* ids (Fig. 5b setup).

    Independent minibatching: every PE caches any vertex it touches, so
    hot vertices occupy P cache slots globally.  Cooperative: vertices
    are fetched only by their owner, so the global effective capacity is
    P * capacity with zero duplication.
    """

    num_pes: int
    capacity_per_pe: int
    caches: list = field(default_factory=list)

    def __post_init__(self):
        if not self.caches:
            self.caches = [LRUCache(self.capacity_per_pe) for _ in range(self.num_pes)]

    def access(self, per_pe_ids: np.ndarray) -> int:
        """per_pe_ids: (P, n) padded id batches; returns total misses."""
        return sum(
            self.caches[p].access_batch(per_pe_ids[p]) for p in range(self.num_pes)
        )

    @property
    def miss_rate(self) -> float:
        h = sum(c.hits for c in self.caches)
        m = sum(c.misses for c in self.caches)
        return m / (h + m) if (h + m) else 0.0

    def reset_stats(self) -> None:
        for c in self.caches:
            c.reset_stats()
