"""Independent minibatching (§2.3) — the paper's baseline.

Builds a static-shape L-layer ``Minibatch`` plan from a seed frontier:
frontiers ``S^0 ⊂ S^1 ⊂ ... ⊂ S^L`` (eq. 2, self-inclusive), one padded
bipartite block per layer with neighbor indices resolved *into the next
frontier* so the forward pass is pure gathers.

Every capacity is static (see :class:`CapacityPlan`), which is what lets
the whole sampling pipeline ``jax.jit``/lower for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import frontier
from repro.core.graph import Graph, INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers.base import Sampler


@dataclass(frozen=True)
class MinibatchLayer:
    """Bipartite block S~^{l+1} -> S^l with indices into frontier l+1."""

    seeds: jax.Array          # (cap_l,) dst vertex ids (= S^l), sorted+padded
    self_idx: jax.Array       # (cap_l,) position of each seed in S^{l+1}
    nbr_idx: jax.Array        # (cap_l, w) positions of sampled srcs in S^{l+1}
    mask: jax.Array           # (cap_l, w)
    etypes: Optional[jax.Array]  # (cap_l, w) relation ids or None

    @property
    def num_dst(self):
        return frontier.count_valid(self.seeds)

    @property
    def num_edges(self):
        return jnp.sum(self.mask)


@dataclass(frozen=True)
class Minibatch:
    """L-layer plan; ``input_ids`` = S^L (the vertices whose features load).

    Satisfies the :class:`repro.engine.Plan` protocol: uniform
    ``layers``/``input_ids``/``seed_ids`` plus :meth:`gather_inputs` and
    :meth:`stats`, so consumers can stay mode-agnostic.  Leaves may carry
    a leading PE axis when built stacked (``jax.vmap`` over seed rows).
    """

    layers: tuple[MinibatchLayer, ...]
    input_ids: jax.Array  # (cap_L,) or (P, cap_L) when stacked
    seed_ids: jax.Array   # (cap_0,) = layers[0].seeds

    @property
    def num_inputs(self):
        return frontier.count_valid(self.input_ids)

    def gather_inputs(self, store) -> jax.Array:
        """Input-layer embeddings from a :class:`FeatureStore`-like object."""
        return store.gather(self.input_ids)

    def stats(self) -> dict:
        """Uniform per-layer counts: S{l}, E{l}, inputs, comm{l+1} (=0).

        Scalars for a single plan; *max over the PE axis* for a stacked
        plan (same convention as cooperative ``plan_stats``).
        """
        stacked = self.input_ids.ndim > 1
        red = (lambda x: int(jnp.max(x))) if stacked else (lambda x: int(x))
        out = {}
        for l, layer in enumerate(self.layers):
            out[f"S{l}"] = red(jnp.sum(layer.seeds != INVALID, axis=-1))
            out[f"E{l}"] = red(jnp.sum(layer.mask, axis=(-2, -1)))
            out[f"comm{l+1}"] = 0  # independent mode never communicates
        out[f"S{len(self.layers)}"] = red(jnp.sum(self.input_ids != INVALID, axis=-1))
        out["inputs"] = out[f"S{len(self.layers)}"]
        return out


jax.tree_util.register_pytree_node(
    MinibatchLayer,
    lambda b: ((b.seeds, b.self_idx, b.nbr_idx, b.mask, b.etypes), None),
    lambda _, c: MinibatchLayer(*c),
)
jax.tree_util.register_pytree_node(
    Minibatch,
    lambda m: ((m.layers, m.input_ids, m.seed_ids), None),
    lambda _, c: Minibatch(tuple(c[0]), c[1], c[2]),
)


@dataclass(frozen=True)
class CapacityPlan:
    """Static frontier capacities cap_0..cap_L.

    Default policy: ``cap_{l+1} = min(cap_l * (fanout_growth), V)`` with a
    safety factor; concavity (Thm 3.2) means true sizes grow *slower*
    than this geometric bound, so overflow only happens when the bound
    is deliberately undersized.
    """

    caps: tuple[int, ...]

    @staticmethod
    def geometric(
        batch_size: int,
        num_layers: int,
        fanout: int,
        num_vertices: int,
        safety: float = 1.25,
        round_to: int = 8,
    ) -> "CapacityPlan":
        caps = [batch_size]
        for _ in range(num_layers):
            nxt = min(int(caps[-1] * (fanout + 1) * safety), num_vertices)
            nxt = -(-nxt // round_to) * round_to
            caps.append(nxt)
        return CapacityPlan(tuple(caps))

    def __getitem__(self, l: int) -> int:
        return self.caps[l]


def build_minibatch(
    graph: Graph,
    sampler: Sampler,
    seeds: jax.Array,
    rng: DependentRNG,
    num_layers: int,
    caps: CapacityPlan,
    backend: str = "reference",
) -> Minibatch:
    """Sample an L-layer minibatch plan (independent path, Fig. 7a).

    ``backend`` selects how the frontier hot loop lowers: ``"reference"``
    is the jnp sort/searchsorted algebra, ``"fused"`` routes dedup + rank
    resolution through one :func:`repro.core.frontier.unique_with_inverse`
    sweep (Pallas on TPU).  Outputs are bit-identical.
    """
    frontier._check_backend(backend)
    S_l = frontier.unique_compact(seeds, caps[0], backend=backend)
    layers = []
    for l in range(num_layers):
        ls = sampler.sample_layer(graph, S_l, rng, l)
        cat = jnp.concatenate([S_l, ls.nbr.reshape(-1)])
        S_next, inv = frontier.unique_with_inverse(cat, caps[l + 1], backend=backend)
        self_idx = inv[: S_l.shape[0]]
        nbr_idx = inv[S_l.shape[0]:].reshape(ls.nbr.shape)
        layers.append(
            MinibatchLayer(
                seeds=S_l,
                self_idx=self_idx,
                nbr_idx=nbr_idx,
                mask=ls.mask & (nbr_idx >= 0),
                etypes=ls.etypes,
            )
        )
        S_l = S_next
    return Minibatch(layers=tuple(layers), input_ids=S_l, seed_ids=layers[0].seeds)


def layer_to_coo(
    layer: MinibatchLayer,
    cap_edges: int,
    backend: str = "reference",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Padded COO view of one bipartite block for plan-local assembly.

    Returns ``(rows, cols, indptr)``: ``indptr`` (cap_l+1,) counts valid
    edges per dst row; ``rows[e]``/``cols[e]`` give the dst row and the
    src position (into ``S^{l+1}``) of edge slot ``e`` in row-major mask
    order, ``-1`` past the total edge count.  Edges beyond ``cap_edges``
    are dropped deterministically (callers size ``cap_edges`` at
    ``cap_l * row_width`` so this never fires).
    """
    frontier._check_backend(backend)
    counts = jnp.sum(layer.mask, axis=1).astype(jnp.int32)
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
    )
    if backend == "fused":
        from repro import kernels

        rows = kernels.expand_indptr(indptr, cap_edges)
    else:
        from repro.kernels.expand_indptr.ref import expand_indptr_ref

        rows = expand_indptr_ref(indptr, cap_edges)
    pos = jnp.cumsum(layer.mask, axis=1).astype(jnp.int32) - 1
    flat = indptr[:-1, None] + pos
    flat = jnp.where(layer.mask & (flat < cap_edges), flat, cap_edges)
    cols = (
        jnp.full((cap_edges + 1,), -1, jnp.int32)
        .at[flat.reshape(-1)]
        .set(jnp.where(layer.mask, layer.nbr_idx, -1).reshape(-1))[:cap_edges]
    )
    rows = jnp.where(cols >= 0, rows, -1)
    return rows, cols, indptr


def epoch_stats(mb: Minibatch) -> dict:
    """Vertex/edge counts per layer — the quantities in Fig 3 / Table 7."""
    out = {}
    for l, layer in enumerate(mb.layers):
        out[f"S{l}"] = int(layer.num_dst)
        out[f"E{l}"] = int(layer.num_edges)
    out[f"S{len(mb.layers)}"] = int(mb.num_inputs)
    return out
