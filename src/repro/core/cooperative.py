"""Cooperative Minibatching (§3.1, Algorithm 1) — the paper's contribution.

One *global* minibatch of size ``B = b·P`` is processed by all ``P`` PEs
together.  The graph is 1-D partitioned (vertex + in-edges owned by one
PE).  Every sampling hop and every forward/backward layer redistributes
vertex ids / embeddings / gradients to owner PEs with an **all-to-all**.

Execution backends
------------------
The same per-PE code runs under two executors:

* :class:`SimExecutor` — PEs are a stacked leading axis ``(P, ...)``;
  per-PE compute is ``jax.vmap``; the all-to-all is an axis transpose.
  Runs on one device; used by tests/benchmarks and as the semantics
  oracle.
* :class:`ShardExecutor` — per-PE code runs inside ``shard_map`` over a
  mesh axis; the all-to-all is ``jax.lax.all_to_all`` (ICI on TPU).
  This is the production path exercised by the dry-run and the
  multi-device subprocess tests.

Exchange convention: each PE holds a buffer ``x`` of shape
``(P, cap, ...)`` whose slice ``x[q]`` is destined for PE ``q``;
``exchange`` returns ``y`` with ``y[q]`` = what PE ``q`` sent here.
``lax.all_to_all(split_axis=0, concat_axis=0, tiled=True)`` implements
exactly this, and — crucially — it has a transpose rule, so running
``jax.grad`` through the cooperative forward pass derives the paper's
backward-pass all-to-alls (Alg. 1, last loop) automatically.

Static shapes: bucket capacities are fixed; over-capacity vertices are
*dropped deterministically* (counted in ``plan_stats``) — capacities are
sized from the concavity bound so this never fires in practice.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Protocol

import jax
import jax.numpy as jnp

from repro.core import frontier
from repro.core.graph import Graph, INVALID
from repro.core.partition import Partition
from repro.core.rng import DependentRNG
from repro.core.samplers.base import Sampler


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
class Executor(Protocol):
    num_pes: int

    def pe(self, fn: Callable, *args):
        """Run a pure per-PE function on every PE."""

    def exchange(self, x: jax.Array) -> jax.Array:
        """Bucketed all-to-all; see module docstring for the convention."""


@dataclass(frozen=True)
class SimExecutor:
    """Single-device simulation: PEs = stacked leading axis, A2A = swap."""

    num_pes: int

    def pe(self, fn, *args):
        return jax.vmap(fn)(*args)

    def exchange(self, x):
        # x: (P_src, P_dst, cap, ...) stacked over source PEs
        return jnp.swapaxes(x, 0, 1)


@dataclass(frozen=True)
class ShardExecutor:
    """shard_map backend: per-PE bodies run on their own mesh shard."""

    num_pes: int
    axis_name: str = "data"

    def pe(self, fn, *args):
        return fn(*args)

    def exchange(self, x):
        return jax.lax.all_to_all(
            x, self.axis_name, split_axis=0, concat_axis=0, tiled=True
        )


# --------------------------------------------------------------------------
# Plan structures
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class CoopLayer:
    """One cooperative layer: local block + cached exchange mappings.

    The forward pass converts owned embeddings ``H`` (rows = S^{l+1}) into
    request-side embeddings ``H~`` (rows = S~^{l+1}) via
    ``redistribute``; the bipartite compute then uses only local indices.
    """

    seeds: jax.Array          # (cap_l,) owned dst ids S_p^l
    self_idx: jax.Array       # (cap_l,) into S~^{l+1}
    nbr_idx: jax.Array        # (cap_l, w) into S~^{l+1}
    mask: jax.Array           # (cap_l, w)
    etypes: Optional[jax.Array]
    slot_to_tilde: jax.Array  # (P, cap_bucket) scatter: bucket slot -> S~ row
    req_idx: jax.Array        # (P, cap_bucket) gather: peer request -> S^{l+1} row
    tilde_ids: jax.Array      # (cap_tilde,) S~^{l+1} vertex ids (debug/tests)


@dataclass(frozen=True)
class CoopMinibatch:
    """Cooperative L-layer plan.

    Satisfies the :class:`repro.engine.Plan` protocol (``layers`` /
    ``input_ids`` / ``seed_ids`` / :meth:`gather_inputs` / :meth:`stats`)
    alongside :class:`repro.core.minibatch.Minibatch`.  Under
    :class:`SimExecutor` every leaf carries a leading ``(P, ...)`` axis.
    """

    layers: tuple[CoopLayer, ...]
    input_ids: jax.Array  # (cap_L,) owned S_p^L — features this PE fetches
    seed_ids: jax.Array

    def gather_inputs(self, store) -> jax.Array:
        """Owned input embeddings (no cross-PE duplication, Fig. 7b)."""
        return store.gather(self.input_ids)

    def stats(self) -> dict:
        """Per-PE max counts (Table 7).  Requires the stacked Sim layout."""
        if self.seed_ids.ndim != 2 or self.layers[0].slot_to_tilde.ndim != 3:
            raise ValueError(
                "CoopMinibatch.stats() needs the stacked SimExecutor layout; "
                "plans built per-PE under ShardExecutor have no global view"
            )
        return plan_stats(self, SimExecutor(self.seed_ids.shape[0]))


jax.tree_util.register_pytree_node(
    CoopLayer,
    lambda b: (
        (
            b.seeds,
            b.self_idx,
            b.nbr_idx,
            b.mask,
            b.etypes,
            b.slot_to_tilde,
            b.req_idx,
            b.tilde_ids,
        ),
        None,
    ),
    lambda _, c: CoopLayer(*c),
)
jax.tree_util.register_pytree_node(
    CoopMinibatch,
    lambda m: ((m.layers, m.input_ids, m.seed_ids), None),
    lambda _, c: CoopMinibatch(tuple(c[0]), c[1], c[2]),
)


@dataclass(frozen=True)
class CoopCapacityPlan:
    """Static capacities: owned frontier, request frontier, A2A bucket."""

    caps: tuple[int, ...]         # owned S_p^l capacity, l = 0..L
    tilde_caps: tuple[int, ...]   # S~_p^{l+1} capacity, l = 0..L-1
    bucket_caps: tuple[int, ...]  # per-peer A2A bucket, l = 0..L-1

    @staticmethod
    def geometric(
        local_batch: int,
        num_layers: int,
        fanout: int,
        num_vertices: int,
        num_pes: int,
        safety: float = 1.5,
        bucket_safety: float = 2.5,
        round_to: int = 8,
    ) -> "CoopCapacityPlan":
        rnd = lambda x: -(-int(x) // round_to) * round_to
        caps = [rnd(local_batch)]
        tilde, buckets = [], []
        for _ in range(num_layers):
            t = min(rnd(caps[-1] * (fanout + 1) * safety), num_vertices)
            tilde.append(t)
            buckets.append(rnd(t // num_pes * bucket_safety + fanout))
            caps.append(min(rnd(t * safety), num_vertices))
        return CoopCapacityPlan(tuple(caps), tuple(tilde), tuple(buckets))


# --------------------------------------------------------------------------
# Plan building (cooperative sampling — Alg. 1, first loop)
# --------------------------------------------------------------------------
def _bucketize(ids: jax.Array, owners: jax.Array, num_pes: int, cap_bucket: int):
    """Partition a padded id vector into per-owner buckets.

    Returns (bucket_ids (P, cap), slot_to_src (P, cap)) where slot_to_src
    maps each bucket slot back to its position in ``ids`` (-1 padding).
    """
    n = ids.shape[0]
    valid = ids != INVALID
    owners = jnp.where(valid, owners, num_pes)  # park padding in a ghost bucket
    order = jnp.argsort(owners, stable=True)
    sorted_owner = owners[order]
    sorted_ids = ids[order]
    group_start = jnp.searchsorted(sorted_owner, jnp.arange(num_pes + 1))
    rank = jnp.arange(n) - group_start[jnp.clip(sorted_owner, 0, num_pes)]
    ok = (sorted_owner < num_pes) & (rank < cap_bucket)
    flat_pos = jnp.where(
        ok, sorted_owner * cap_bucket + rank, num_pes * cap_bucket
    )
    bucket_ids = (
        jnp.full((num_pes * cap_bucket + 1,), INVALID, ids.dtype)
        .at[flat_pos]
        .set(jnp.where(ok, sorted_ids, INVALID))[: num_pes * cap_bucket]
        .reshape(num_pes, cap_bucket)
    )
    slot_to_src = (
        jnp.full((num_pes * cap_bucket + 1,), -1, jnp.int32)
        .at[flat_pos]
        .set(jnp.where(ok, order.astype(jnp.int32), -1))[: num_pes * cap_bucket]
        .reshape(num_pes, cap_bucket)
    )
    return bucket_ids, slot_to_src


def build_cooperative_minibatch(
    graph: Graph,
    sampler: Sampler,
    part: Partition,
    seeds: jax.Array,  # per-PE owned seed frontier (stacked (P, b) under Sim)
    rng: DependentRNG,
    num_layers: int,
    caps: CoopCapacityPlan,
    ex: Executor,
    backend: str = "reference",
) -> CoopMinibatch:
    frontier._check_backend(backend)
    P = ex.num_pes

    def local_seeds(s):
        return frontier.unique_compact(s, caps.caps[0], backend=backend)

    S_l = ex.pe(local_seeds, seeds)
    layers = []
    for l in range(num_layers):
        cap_t, cap_b, cap_next = caps.tilde_caps[l], caps.bucket_caps[l], caps.caps[l + 1]

        def sample_and_bucket(S):
            ls = sampler.sample_layer(graph, S, rng, l)
            cat = jnp.concatenate([S, ls.nbr.reshape(-1)])
            tilde, inv = frontier.unique_with_inverse(cat, cap_t, backend=backend)
            self_idx = inv[: S.shape[0]]
            nbr_idx = inv[S.shape[0]:].reshape(ls.nbr.shape)
            owners = part.owner_of(tilde)
            bucket_ids, slot_to_tilde = _bucketize(tilde, owners, P, cap_b)
            return ls, tilde, nbr_idx, self_idx, bucket_ids, slot_to_tilde

        ls, tilde, nbr_idx, self_idx, bucket_ids, slot_to_tilde = ex.pe(
            sample_and_bucket, S_l
        )
        req = ex.exchange(bucket_ids)  # ids owned here, requested per peer

        def next_frontier(req):
            # one fused dedup resolves BOTH the next owned frontier and
            # every peer request slot — the separate lookup pass is gone
            S_next, inv = frontier.unique_with_inverse(
                req.reshape(-1), cap_next, backend=backend
            )
            return S_next, inv.reshape(req.shape)

        S_next, req_idx = ex.pe(next_frontier, req)
        layers.append(
            CoopLayer(
                seeds=S_l,
                self_idx=self_idx,
                nbr_idx=nbr_idx,
                mask=ls.mask & (nbr_idx >= 0),
                etypes=ls.etypes,
                slot_to_tilde=slot_to_tilde,
                req_idx=req_idx,
                tilde_ids=tilde,
            )
        )
        S_l = S_next
    seed_ids = layers[0].seeds
    return CoopMinibatch(layers=tuple(layers), input_ids=S_l, seed_ids=seed_ids)


# --------------------------------------------------------------------------
# Embedding redistribution (Alg. 1 forward loop; backward via AD transpose)
# --------------------------------------------------------------------------
def redistribute(
    ex: Executor, layer: CoopLayer, H: jax.Array, cap_tilde: int
) -> jax.Array:
    """Convert owned embeddings H (rows = S^{l+1}) to H~ (rows = S~^{l+1}).

    Differentiable: reverse-mode AD through ``exchange`` yields the
    backward-pass all-to-all of Alg. 1 (gradient redistribution to owners)
    with no hand-written transpose.
    """

    def gather_send(H, req_idx):
        send = H[jnp.clip(req_idx, 0)]  # (P, cap_b, d)
        return jnp.where((req_idx >= 0)[..., None], send, 0.0)

    send = ex.pe(gather_send, H, layer.req_idx)
    recv = ex.exchange(send)

    def scatter(recv, slot_to_tilde):
        d = recv.shape[-1]
        pos = jnp.where(slot_to_tilde >= 0, slot_to_tilde, cap_tilde).reshape(-1)
        out = (
            jnp.zeros((cap_tilde + 1, d), recv.dtype).at[pos].set(recv.reshape(-1, d))
        )
        return out[:cap_tilde]

    return ex.pe(scatter, recv, layer.slot_to_tilde)


def plan_stats(mb: CoopMinibatch, ex: Executor) -> dict:
    """Per-PE max counts (Table 7 columns): |S^l|, |E^l|, |S~^l|, c|S~^l|.

    Only meaningful under :class:`SimExecutor` (stacked PE axis).
    """
    assert isinstance(ex, SimExecutor)
    P = ex.num_pes
    off_diag = ~jnp.eye(P, dtype=bool)  # (P_src, P_owner)
    stats = {}
    for l, layer in enumerate(mb.layers):
        stats[f"S{l}"] = int(jnp.max(jnp.sum(layer.seeds != INVALID, axis=-1)))
        stats[f"E{l}"] = int(jnp.max(jnp.sum(layer.mask, axis=(-2, -1))))
        filled = layer.slot_to_tilde >= 0  # (P, P, cap_b)
        stats[f"tilde{l+1}"] = int(jnp.max(jnp.sum(filled, axis=(-2, -1))))
        cross = jnp.sum(filled & off_diag[:, :, None], axis=(-2, -1))
        stats[f"comm{l+1}"] = int(jnp.max(cross))
    stats["inputs"] = int(jnp.max(jnp.sum(mb.input_ids != INVALID, axis=-1)))
    return stats
