"""Dependent consecutive minibatches (§3.2 + A.7).

Two constructions from the paper:

* **Nested** (§3.2): sample one kappa*b-sized batch, then carve kappa
  b-sized minibatches out of it.  Input features of all kappa batches are
  a subset of the big batch's S^L.
* **Smoothed** (A.7, preferred): keep plain b-sized batches but draw
  sampler variates from :class:`DependentRNG`, which interpolates between
  RNG seeds with period kappa.  No nesting, drop-in for NS and LABOR.

This module provides the schedulers; the RNG math lives in
``repro.core.rng``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.core.rng import DependentRNG


@dataclass(frozen=True)
class DependentSchedule:
    """Produces the (rng, seed-batch) stream for smoothed dependency."""

    base_seed: int
    kappa: Optional[int]  # None = infinite dependency

    def rng_at(self, step: int) -> DependentRNG:
        return DependentRNG(self.base_seed, self.kappa, step)


@dataclass
class NestedSchedule:
    """Nested dependent minibatching (§3.2): kappa sub-batches per group.

    ``next_sub_batch(step, big_batch_ids)`` partitions the kappa*b group
    batch into kappa disjoint b-sized sub-batches, reshuffled per group.
    """

    base_seed: int
    kappa: int
    sub_batch_size: int

    def group_index(self, step: int) -> int:
        return step // self.kappa

    def sub_batch(self, step: int, group_ids: np.ndarray) -> np.ndarray:
        g, i = divmod(step, self.kappa)
        order = np.random.default_rng(self.base_seed + 31 * g).permutation(
            len(group_ids)
        )
        sel = order[i * self.sub_batch_size : (i + 1) * self.sub_batch_size]
        return np.asarray(group_ids)[sel]

    def rng_for_group(self, step: int) -> DependentRNG:
        # one frozen RNG per group: all sub-batches share neighborhoods
        return DependentRNG(self.base_seed + self.group_index(step), None, 0)
