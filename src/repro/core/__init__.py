"""Core: the paper's contribution — cooperative & dependent minibatching."""
from repro.core.graph import Graph, INVALID
from repro.core.partition import Partition, make_partition, cross_edge_ratio
from repro.core.rng import DependentRNG
from repro.core.minibatch import (
    CapacityPlan,
    Minibatch,
    MinibatchLayer,
    build_minibatch,
)
from repro.core.cooperative import (
    CoopCapacityPlan,
    CoopLayer,
    CoopMinibatch,
    SimExecutor,
    ShardExecutor,
    build_cooperative_minibatch,
    redistribute,
    plan_stats,
)
from repro.core.dependent import DependentSchedule, NestedSchedule
from repro.core.cache import LRUCache, CooperativeCacheArray
from repro.core.feature_loader import FeatureStore

__all__ = [
    "Graph",
    "INVALID",
    "Partition",
    "make_partition",
    "cross_edge_ratio",
    "DependentRNG",
    "CapacityPlan",
    "Minibatch",
    "MinibatchLayer",
    "build_minibatch",
    "CoopCapacityPlan",
    "CoopLayer",
    "CoopMinibatch",
    "SimExecutor",
    "ShardExecutor",
    "build_cooperative_minibatch",
    "redistribute",
    "plan_stats",
    "DependentSchedule",
    "NestedSchedule",
    "LRUCache",
    "CooperativeCacheArray",
    "FeatureStore",
]
