"""Core: the paper's contribution — cooperative & dependent minibatching.

Two layers live here:

* the **kernel layer** — the low-level builders (``build_minibatch``,
  ``build_cooperative_minibatch``), capacity plans, partitions, RNG
  schedules, caches; stable, mode-specific, fully jittable;
* the **facade** — :class:`repro.engine.MinibatchEngine` and friends,
  re-exported below, which wire the kernel layer behind one config so
  consumers never branch on minibatching mode.
"""
from repro.core.graph import Graph, GraphValidationError, INVALID
from repro.core.partition import (
    Partition,
    cross_edge_ratio,
    degree_balanced_partition,
    make_partition,
    ownership_balance,
)
from repro.core.rng import DependentRNG
from repro.core.minibatch import (
    CapacityPlan,
    Minibatch,
    MinibatchLayer,
    build_minibatch,
)
from repro.core.cooperative import (
    CoopCapacityPlan,
    CoopLayer,
    CoopMinibatch,
    SimExecutor,
    ShardExecutor,
    build_cooperative_minibatch,
    redistribute,
    plan_stats,
)
from repro.core.dependent import DependentSchedule, NestedSchedule
from repro.core.cache import LRUCache, CooperativeCacheArray
from repro.core.feature_loader import FeatureStore

__all__ = [
    "Graph",
    "GraphValidationError",
    "INVALID",
    "Partition",
    "make_partition",
    "cross_edge_ratio",
    "degree_balanced_partition",
    "ownership_balance",
    "DependentRNG",
    "CapacityPlan",
    "Minibatch",
    "MinibatchLayer",
    "build_minibatch",
    "CoopCapacityPlan",
    "CoopLayer",
    "CoopMinibatch",
    "SimExecutor",
    "ShardExecutor",
    "build_cooperative_minibatch",
    "redistribute",
    "plan_stats",
    "DependentSchedule",
    "NestedSchedule",
    "LRUCache",
    "CooperativeCacheArray",
    "FeatureStore",
    # engine facade (lazy re-exports, see __getattr__)
    "CacheConfig",
    "CapacityPolicy",
    "EngineConfig",
    "MinibatchEngine",
    "MinibatchStream",
    "Plan",
    "StreamItem",
]

_ENGINE_EXPORTS = {
    "CacheConfig",
    "CapacityPolicy",
    "EngineConfig",
    "MinibatchEngine",
    "MinibatchStream",
    "Plan",
    "StreamItem",
}


def __getattr__(name):
    # Lazy: repro.engine imports the kernel modules above, so a direct
    # top-of-file import here would be circular.
    if name in _ENGINE_EXPORTS:
        import repro.engine as _engine

        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
