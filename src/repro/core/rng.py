"""Stateless, counter-based randomness for samplers.

The paper's dependent-minibatching smoothing (Appendix A.7) requires that
the random variate attached to a vertex ``t`` (LABOR) or an edge
``(t, s)`` (NS) is a *pure function of (seed z, t[, s])* — re-rolling with
the same seed must reproduce the same variate.  We therefore derive all
sampler randomness from an integer mixing function instead of stateful
PRNG streams.

Smoothed interpolation between two seeds ``z1 -> z2`` (A.7):

    n_ts(c) = cos(c*pi/2) * n1_ts + sin(c*pi/2) * n2_ts,   c = i / kappa
    r_ts    = Phi(n_ts(c))  ~  U(0, 1)   for every c

so neighborhoods drift continuously and are fully refreshed every kappa
iterations, while each step's marginal distribution stays exactly uniform
(unbiased sampler at every step).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


def _mix(x: jax.Array) -> jax.Array:
    """splitmix64-style avalanche on uint32 (fixed-point, vectorized)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_u32(ids: jax.Array, seed, salt=0) -> jax.Array:
    """Deterministic uint32 hash of integer ids under (seed, salt).

    ``seed`` and ``salt`` may be python ints or (traced) integer arrays.
    """
    seed = jnp.asarray(seed).astype(jnp.uint32)
    salt = jnp.asarray(salt).astype(jnp.uint32)
    h = _mix(jnp.asarray(ids).astype(jnp.uint32) ^ (seed * jnp.uint32(0x9E3779B9)))
    h = _mix(h ^ (salt * jnp.uint32(0x85EBCA6B)))
    return h


def hash_pair_u32(a: jax.Array, b: jax.Array, seed, salt: int = 0) -> jax.Array:
    """Hash of an id pair (edge (t, s)); order-sensitive."""
    ha = hash_u32(a, seed, salt)
    return _mix(ha ^ _mix(b.astype(jnp.uint32) ^ jnp.uint32(0xDEADBEEF)))


def uniform_from_u32(h: jax.Array) -> jax.Array:
    """uint32 -> float32 in the open interval (0, 1)."""
    return (h.astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 4294967296.0)


def uniform_from_ids(ids, seed, salt: int = 0) -> jax.Array:
    return uniform_from_u32(hash_u32(ids, seed, salt))


def normal_from_ids(ids, seed, salt: int = 0) -> jax.Array:
    """Standard normal via inverse-CDF of the hashed uniform."""
    return norm.ppf(uniform_from_ids(ids, seed, salt))


def normal_from_pairs(a, b, seed, salt: int = 0) -> jax.Array:
    return norm.ppf(uniform_from_u32(hash_pair_u32(a, b, seed, salt)))


@dataclass(frozen=True)
class RNGState:
    """Dynamic smoothed-RNG state: two seeds + interpolation coefficient.

    A pytree of scalars, so it threads through ``jax.jit`` as a *dynamic*
    argument — one compiled train step serves every iteration of a
    dependent-minibatching run (no per-step retrace).

    ``c == 0`` reduces exactly to independent sampling since
    ``Phi(Phi^{-1}(u)) == u``.
    """

    z1: jax.Array  # uint32 scalar
    z2: jax.Array  # uint32 scalar
    c: jax.Array   # float32 scalar in [0, 1)

    def vertex_uniform(self, ids: jax.Array, salt: int = 0) -> jax.Array:
        """r_t ~ U(0,1), smoothly drifting with step (LABOR variates)."""
        n1 = normal_from_ids(ids, self.z1, salt)
        n2 = normal_from_ids(ids, self.z2, salt)
        n = jnp.cos(self.c * jnp.pi / 2) * n1 + jnp.sin(self.c * jnp.pi / 2) * n2
        return norm.cdf(n)

    def edge_uniform(self, t: jax.Array, s: jax.Array, salt: int = 0) -> jax.Array:
        """r_ts ~ U(0,1) per edge (NS variates), smoothly drifting."""
        n1 = normal_from_pairs(t, s, self.z1, salt)
        n2 = normal_from_pairs(t, s, self.z2, salt)
        n = jnp.cos(self.c * jnp.pi / 2) * n1 + jnp.sin(self.c * jnp.pi / 2) * n2
        return norm.cdf(n)

    def fold(self, salt: int) -> jax.Array:
        """Derive a uint32 sub-seed (e.g. random-walk streams)."""
        return (
            self.z1 * jnp.uint32(0x9E3779B9) + jnp.uint32(salt) * jnp.uint32(0x85EBCA6B)
        )


jax.tree_util.register_pytree_node(
    RNGState,
    lambda s: ((s.z1, s.z2, s.c), None),
    lambda _, ch: RNGState(*ch),
)


@dataclass(frozen=True)
class DependentRNG:
    """Seed schedule implementing smoothed dependent minibatching (A.7).

    ``kappa`` is the dependency window; ``step`` the global iteration.
    ``kappa = 1``   -> fully independent batches (fresh seed every step).
    ``kappa = None``-> infinite dependency (static neighborhoods).

    Seeds for window ``w = step // kappa`` are ``base + w`` (z1) and
    ``base + w + 1`` (z2); the interpolation coefficient is
    ``c = (step % kappa) / kappa``.  ``step`` may be a python int or a
    traced array (``state_at``), so a single compiled train step covers
    the whole schedule.
    """

    base_seed: int
    kappa: int | None = 1
    step: int = 0

    def at_step(self, step: int) -> "DependentRNG":
        return DependentRNG(self.base_seed, self.kappa, step)

    def state_at(self, step) -> RNGState:
        base = jnp.uint32(self.base_seed & 0xFFFFFFFF)
        if self.kappa is None:  # infinite dependency
            return RNGState(base, base, jnp.float32(0.0))
        step = jnp.asarray(step, jnp.int32)
        window = step // self.kappa
        i = step % self.kappa
        c = i.astype(jnp.float32) / self.kappa
        z1 = base + window.astype(jnp.uint32)
        return RNGState(z1, z1 + jnp.uint32(1), c)

    @property
    def state(self) -> RNGState:
        return self.state_at(self.step)

    # convenience passthroughs (host-side use in tests/benchmarks)
    def vertex_uniform(self, ids: jax.Array, salt: int = 0) -> jax.Array:
        return self.state.vertex_uniform(ids, salt)

    def edge_uniform(self, t: jax.Array, s: jax.Array, salt: int = 0) -> jax.Array:
        return self.state.edge_uniform(t, s, salt)

    def fold(self, salt: int):
        return self.state.fold(salt)
