"""1-D graph partitioning for Cooperative Minibatching (§3.1).

Each vertex (and its incoming edges) is logically owned by one PE.  The
paper uses random partitioning by default (cross-edge ratio
``c ≈ (P-1)/P``) and METIS for reduced communication.  METIS is not
available offline, so we provide a greedy multi-source BFS grower as the
quality-partitioner proxy — it delivers the same qualitative effect the
paper reports (lower ``c`` => smaller all-to-all volume, Table 7).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Partition:
    """Vertex -> PE ownership map."""

    owner: jax.Array  # (V,) int32 in [0, P)
    num_parts: int

    def owner_of(self, ids: jax.Array) -> jax.Array:
        from repro.core.graph import INVALID

        safe = jnp.where(ids == INVALID, 0, ids)
        own = self.owner[safe]
        return jnp.where(ids == INVALID, self.num_parts - 1, own)

    def local_rank(self, ids: jax.Array) -> jax.Array:
        """Stable intra-part index (hash order); used for bucketed A2A."""
        return ids % jnp.int32(max(1, self.num_parts))


def hash_partition(num_vertices: int, num_parts: int) -> Partition:
    """Random (hash) partitioning — the paper's default, c ~ (P-1)/P."""
    v = np.arange(num_vertices, dtype=np.uint64)
    h = (v * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(33)
    owner = (h % np.uint64(num_parts)).astype(np.int32)
    return Partition(owner=jnp.asarray(owner), num_parts=num_parts)


def block_partition(num_vertices: int, num_parts: int) -> Partition:
    """Contiguous blocks (locality-friendly for RMAT-ordered ids)."""
    owner = np.minimum(
        np.arange(num_vertices, dtype=np.int64) * num_parts // num_vertices,
        num_parts - 1,
    ).astype(np.int32)
    return Partition(owner=jnp.asarray(owner), num_parts=num_parts)


def greedy_bfs_partition(graph, num_parts: int, seed: int = 0) -> Partition:
    """Greedy balanced multi-source BFS growing (METIS proxy, host-side).

    Grows ``num_parts`` regions breadth-first from random seeds, always
    extending the currently-smallest region; unreached vertices fall back
    to hash assignment.  Cuts cross-edge ratio well below (P-1)/P on
    graphs with locality.
    """
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    V = graph.num_vertices
    rng = np.random.default_rng(seed)
    owner = np.full(V, -1, dtype=np.int32)
    target = (V + num_parts - 1) // num_parts
    frontiers: list[list[int]] = [[] for _ in range(num_parts)]
    sizes = np.zeros(num_parts, dtype=np.int64)
    for p, s in enumerate(rng.choice(V, size=num_parts, replace=False)):
        owner[s] = p
        frontiers[p].append(int(s))
        sizes[p] = 1
    active = set(range(num_parts))
    while active:
        p = min(active, key=lambda q: sizes[q])
        if not frontiers[p] or sizes[p] >= target:
            active.discard(p)
            continue
        nxt: list[int] = []
        for v in frontiers[p]:
            for t in indices[indptr[v] : indptr[v + 1]]:
                if owner[t] == -1 and sizes[p] < target:
                    owner[t] = p
                    sizes[p] += 1
                    nxt.append(int(t))
        frontiers[p] = nxt
        if not nxt:
            active.discard(p)
    unassigned = owner == -1
    if unassigned.any():
        fallback = np.asarray(hash_partition(V, num_parts).owner)
        owner[unassigned] = fallback[unassigned]
    return Partition(owner=jnp.asarray(owner), num_parts=num_parts)


def degree_balanced_partition(
    graph, num_parts: int, seed: int = 0, tol: float = 0.05
) -> Partition:
    """BFS/METIS-style growth balanced by *owned edges*, not vertex count.

    A vertex owns its incoming edges (1-D partitioning, §3.1), so the
    per-PE sampling/SpMM work is proportional to the owned **degree**
    mass, not the vertex count.  Pure vertex-balanced growth leaves hubs
    clustered on one PE and skews per-PE edge counts by 2x+ on power-law
    graphs; this grower extends the region with the smallest owned
    degree and caps regions at ``(1 + tol)`` of the mean degree load.

    A final ownership-balancing pass then walks parts whose *vertex*
    count exceeds ``(1 + tol)`` of the mean and reassigns their
    lowest-degree vertices to the vertex-lightest part — so both loads
    (edges for compute, vertices for seed/ownership balance) end within
    tolerance.  Locality degrades gracefully: moved vertices are the
    cheapest ones, so the cross-edge ratio stays well under (P-1)/P.
    """
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    V = graph.num_vertices
    deg = np.diff(indptr).astype(np.int64)
    rng = np.random.default_rng(seed)
    owner = np.full(V, -1, dtype=np.int32)
    deg_target = (deg.sum() / num_parts) * (1.0 + tol)
    frontiers: list[list[int]] = [[] for _ in range(num_parts)]
    deg_load = np.zeros(num_parts, dtype=np.int64)
    for p, s in enumerate(rng.choice(V, size=num_parts, replace=False)):
        owner[s] = p
        frontiers[p].append(int(s))
        deg_load[p] = deg[s]
    active = set(range(num_parts))
    while active:
        p = min(active, key=lambda q: deg_load[q])
        if not frontiers[p] or deg_load[p] >= deg_target:
            active.discard(p)
            continue
        nxt: list[int] = []
        for v in frontiers[p]:
            for t in indices[indptr[v] : indptr[v + 1]]:
                if owner[t] == -1 and deg_load[p] < deg_target:
                    owner[t] = p
                    deg_load[p] += deg[t]
                    nxt.append(int(t))
        frontiers[p] = nxt
        if not nxt:
            active.discard(p)
    unassigned = np.nonzero(owner == -1)[0]
    if len(unassigned):
        # park stragglers on the degree-lightest part round-robin
        order = np.argsort(deg_load)
        owner[unassigned] = np.asarray(order, np.int32)[
            np.arange(len(unassigned)) % num_parts
        ]
    _rebalance_ownership(owner, deg, num_parts, tol)
    return Partition(owner=jnp.asarray(owner), num_parts=num_parts)


def _rebalance_ownership(
    owner: np.ndarray, deg: np.ndarray, num_parts: int, tol: float
) -> None:
    """In-place vertex-count balancing: shed the cheapest (lowest-degree)
    vertices from over-full parts onto the vertex-lightest part."""
    counts = np.bincount(owner, minlength=num_parts).astype(np.int64)
    cap = int(np.ceil(counts.mean() * (1.0 + tol)))
    for p in range(num_parts):
        if counts[p] <= cap:
            continue
        members = np.nonzero(owner == p)[0]
        shed = members[np.argsort(deg[members], kind="stable")]
        for v in shed[: counts[p] - cap]:
            q = int(np.argmin(counts))
            owner[v] = q
            counts[p] -= 1
            counts[q] += 1


def ownership_balance(graph, part: Partition) -> dict:
    """Balance factors (max load / mean load) for both ownership loads.

    ``vertices`` gauges seed/ownership balance, ``edges`` the per-PE
    sampling + SpMM work (a vertex owns its in-edges).  1.0 is perfect.
    """
    owner = np.asarray(part.owner)
    deg = np.diff(np.asarray(graph.indptr)).astype(np.int64)
    counts = np.bincount(owner, minlength=part.num_parts)
    edge_load = np.bincount(owner, weights=deg, minlength=part.num_parts)
    return {
        "vertices": float(counts.max() / max(counts.mean(), 1)),
        "edges": float(edge_load.max() / max(edge_load.mean(), 1.0)),
    }


def cross_edge_ratio(graph, part: Partition) -> float:
    """Fraction ``c`` of edges whose endpoints live on different PEs."""
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    owner = np.asarray(part.owner)
    dst = np.repeat(np.arange(graph.num_vertices), np.diff(indptr))
    cross = owner[indices] != owner[dst]
    return float(cross.mean()) if len(cross) else 0.0


def make_partition(kind: str, graph, num_parts: int, seed: int = 0) -> Partition:
    if kind == "hash":
        return hash_partition(graph.num_vertices, num_parts)
    if kind == "block":
        return block_partition(graph.num_vertices, num_parts)
    if kind in ("bfs", "metis", "greedy"):
        return greedy_bfs_partition(graph, num_parts, seed)
    if kind in ("degree", "degree_balanced"):
        return degree_balanced_partition(graph, num_parts, seed)
    raise ValueError(f"unknown partition kind {kind!r}")
