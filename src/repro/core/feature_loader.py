"""Feature loading: the storage -> PE stage of Table 1.

Independent: each PE gathers features for its own ``S^L`` — vertices
shared between PEs are fetched multiple times (wasted β bandwidth,
Fig. 7a).  Cooperative: each PE fetches only *owned* ``S_p^L`` (zero
duplication) and the first forward-layer all-to-all redistributes them
(Fig. 7b).

``FeatureStore`` also counts fetched rows so benchmarks can report the
paper's bandwidth-savings numbers without real storage hardware.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import INVALID


@dataclass
class FeatureStore:
    """Vertex-embedding storage with fetch accounting."""

    features: jax.Array  # (V, d)

    def gather(self, ids: jax.Array) -> jax.Array:
        """Masked gather; INVALID rows come back as zeros."""
        V = self.features.shape[0]
        h = self.features[jnp.clip(ids, 0, V - 1)]
        return jnp.where((ids != INVALID)[..., None], h, 0.0)

    def count_fetched(self, ids) -> int:
        """Rows actually transferred from storage (unique per PE batch)."""
        ids = np.asarray(ids)
        if ids.ndim == 1:
            u = np.unique(ids)
            return int((u != INVALID).sum())
        return sum(self.count_fetched(row) for row in ids)

    def count_duplicates_across_pes(self, per_pe_ids) -> int:
        """Extra fetches Independent pays vs a perfectly-shared fetch."""
        per_pe_ids = np.asarray(per_pe_ids)
        per_pe_unique = self.count_fetched(per_pe_ids)
        global_unique = int(
            (np.unique(per_pe_ids.ravel()) != INVALID).sum()
        )
        return per_pe_unique - global_unique
