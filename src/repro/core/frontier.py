"""Padded, static-capacity vertex-set operations.

JAX/TPU cannot lower dynamic-size frontiers, so every expansion set
``S^l`` is a fixed-capacity int32 vector padded with ``INVALID`` and kept
*sorted* (valid ids first, then padding — INVALID is int32 max so a plain
sort yields this layout).  All set algebra (union, unique, membership)
reduces to sorts and searchsorted, which lower to efficient TPU sort
networks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import INVALID


def pad_to(ids: jax.Array, cap: int) -> jax.Array:
    """Pad / truncate a 1-D id vector to capacity ``cap``."""
    n = ids.shape[0]
    if n >= cap:
        return ids[:cap]
    return jnp.concatenate([ids, jnp.full((cap - n,), INVALID, ids.dtype)])


@partial(jax.jit, static_argnums=(1,))
def unique_padded(ids: jax.Array, cap: int) -> jax.Array:
    """Sorted unique ids with INVALID padding, capacity ``cap``.

    Overflow policy: if the true unique count exceeds ``cap`` the smallest
    ``cap`` ids are kept (deterministic; callers size capacities from
    fanout budgets so this only triggers under adversarial inputs).
    """
    flat = ids.reshape(-1)
    return jnp.unique(flat, size=cap, fill_value=INVALID)


@partial(jax.jit, static_argnums=(2,))
def union_padded(a: jax.Array, b: jax.Array, cap: int) -> jax.Array:
    return unique_padded(jnp.concatenate([a.reshape(-1), b.reshape(-1)]), cap)


PLAN_BACKENDS = ("reference", "fused")


def _check_backend(backend: str) -> None:
    if backend not in PLAN_BACKENDS:
        raise ValueError(
            f"unknown plan backend {backend!r}; expected one of {PLAN_BACKENDS}"
        )


def unique_with_inverse(
    ids: jax.Array, cap: int, backend: str = "reference"
) -> tuple[jax.Array, jax.Array]:
    """(uniq (cap,), inv (m,)): dedup + rank of every id in the result.

    ``uniq`` equals :func:`unique_padded` and ``inv`` equals
    :func:`lookup` of the flattened input against it — both backends are
    bit-identical; ``"fused"`` routes through the
    :mod:`repro.kernels.unique_compact` sweep (one pass over sorted data
    instead of ``jnp.unique`` plus two ``searchsorted``).
    """
    _check_backend(backend)
    flat = ids.reshape(-1)
    if backend == "fused":
        from repro import kernels

        return kernels.unique_with_inverse(flat, cap)
    uniq = unique_padded(flat, cap)
    return uniq, lookup(uniq, flat)


def unique_compact(ids: jax.Array, cap: int, backend: str = "reference") -> jax.Array:
    """Backend-dispatched :func:`unique_padded` (no inverse)."""
    _check_backend(backend)
    if backend == "fused":
        from repro import kernels

        return kernels.unique_compact(ids.reshape(-1), cap)
    return unique_padded(ids, cap)


@jax.jit
def count_valid(ids: jax.Array) -> jax.Array:
    return jnp.sum(ids != INVALID)


@jax.jit
def lookup(sorted_ids: jax.Array, queries: jax.Array) -> jax.Array:
    """Index of each query in a sorted padded id vector; -1 if absent.

    ``queries`` may contain INVALID (maps to -1).
    """
    pos = jnp.searchsorted(sorted_ids, queries).astype(jnp.int32)
    pos = jnp.clip(pos, 0, sorted_ids.shape[0] - 1)
    hit = (sorted_ids[pos] == queries) & (queries != INVALID)
    return jnp.where(hit, pos, jnp.int32(-1))


@jax.jit
def contains(sorted_ids: jax.Array, queries: jax.Array) -> jax.Array:
    return lookup(sorted_ids, queries) >= 0


@partial(jax.jit, static_argnums=(2,))
def compact(ids: jax.Array, keep: jax.Array, cap: int) -> jax.Array:
    """Keep ``ids[keep]``, drop the rest; result sorted + INVALID-padded."""
    masked = jnp.where(keep, ids, INVALID)
    out = jnp.sort(masked.reshape(-1))
    return pad_to(out, cap)


@partial(jax.jit, static_argnums=(1,))
def multiplicity(sorted_ids: jax.Array, cap: int) -> jax.Array:
    """Occurrence count of each *valid* entry of a sorted padded vector.

    Used by the theory harness to measure |T^l| (eq. 5): vertices reached
    from exactly one seed.
    """
    ids = sorted_ids
    left = jnp.concatenate([jnp.full((1,), -1, ids.dtype), ids[:-1]])
    starts = (ids != left) & (ids != INVALID)
    seg = jnp.cumsum(starts) - 1  # run index per element
    seg = jnp.where(ids == INVALID, cap - 1, seg)
    counts = jnp.zeros((cap,), jnp.int32).at[seg].add(
        jnp.where(ids != INVALID, 1, 0)
    )
    return counts, starts
