"""Sampler interface.

A sampler maps a padded seed frontier ``S^l`` to the sampled in-edges of
that layer: a static-shape table ``nbr[(n, row_width)]`` of source ids
(INVALID padded) and its validity mask.  ``row_width`` is a *static*
per-sampler constant (``k`` for NS, ``max_degree`` for LABOR/Full, ``k``
for RW) so every hop lowers with fixed shapes.

All samplers draw randomness exclusively through a
:class:`repro.core.rng.DependentRNG`, which is what makes the paper's
smoothed dependent minibatching (A.7) a *property of the RNG*, not of any
individual sampling algorithm.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol

import jax

from repro.core.graph import Graph
from repro.core.rng import DependentRNG


@dataclass(frozen=True)
class LayerSample:
    """Sampled in-edges of one layer: dst row i is seeds[i]."""

    seeds: jax.Array  # (n,) int32, INVALID padded, sorted
    nbr: jax.Array    # (n, row_width) int32 source ids, INVALID padded
    mask: jax.Array   # (n, row_width) bool
    etypes: Optional[jax.Array] = None  # (n, row_width) int32 relation ids

    @property
    def num_edges(self):
        import jax.numpy as jnp

        return jnp.sum(self.mask)


jax.tree_util.register_pytree_node(
    LayerSample,
    lambda s: ((s.seeds, s.nbr, s.mask, s.etypes), None),
    lambda _, c: LayerSample(*c),
)

class Sampler(Protocol):
    name: str

    def row_width(self, graph: Graph) -> int:
        ...

    def sample_layer(
        self, graph: Graph, seeds: jax.Array, rng: DependentRNG, layer: int
    ) -> LayerSample:
        ...


def make_sampler(name: str, fanout: int = 10, **kw) -> "Sampler":
    """Factory: 'ns' | 'labor0' | 'labor*' | 'rw' | 'full'."""
    from repro.core.samplers.full import FullSampler
    from repro.core.samplers.labor import LaborSampler
    from repro.core.samplers.neighbor import NeighborSampler
    from repro.core.samplers.random_walk import RandomWalkSampler

    name = name.lower()
    if name in ("ns", "neighbor"):
        return NeighborSampler(fanout=fanout, **kw)
    if name in ("labor0", "labor-0"):
        return LaborSampler(fanout=fanout, importance=False, **kw)
    if name in ("labor*", "labor-*", "labor_star"):
        return LaborSampler(fanout=fanout, importance=True, **kw)
    if name in ("rw", "randomwalk", "random_walk"):
        return RandomWalkSampler(fanout=fanout, **kw)
    if name == "full":
        return FullSampler(**kw)
    raise ValueError(f"unknown sampler {name!r}")
