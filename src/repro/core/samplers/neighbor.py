"""Neighbor Sampling (GraphSAGE; Hamilton et al., 2017) — A.1.1.

For seed ``s`` with degree ``d_s``: keep the whole neighborhood if
``d_s <= k``; otherwise pick ``k`` uniform neighbors without replacement.

Without-replacement selection is done with per-edge random *keys*
``r_ts`` and a bottom-k over the row — equivalent in distribution to
reservoir sampling, but (a) static-shape and (b) keyed off
``DependentRNG.edge_uniform`` so smoothed dependent minibatching drops in
for free (the paper smooths exactly these ``r_ts``, A.7).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph, INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers.base import LayerSample


@dataclass(frozen=True)
class NeighborSampler:
    fanout: int = 10
    name: str = "ns"
    backend: str = "reference"  # neighbor_table backend ("reference"|"fused")

    def row_width(self, graph: Graph) -> int:
        return min(self.fanout, graph.max_degree)

    def sample_layer(
        self, graph: Graph, seeds: jax.Array, rng: DependentRNG, layer: int
    ) -> LayerSample:
        nbr_full, mask_full = graph.neighbor_table(seeds, backend=self.backend)
        seeds_b = jnp.broadcast_to(seeds[:, None], nbr_full.shape)
        keys = rng.edge_uniform(nbr_full, seeds_b, salt=layer)
        k = self.row_width(graph)
        nbr, mask, idx = _bottom_k(nbr_full, mask_full, keys, k)
        etypes = None
        if graph.edge_types is not None:
            et_full = graph.neighbor_edge_types(seeds)
            etypes = jnp.take_along_axis(et_full, idx, axis=1)
        return LayerSample(seeds=seeds, nbr=nbr, mask=mask, etypes=etypes)


@partial(jax.jit, static_argnums=(3,))
def _bottom_k(nbr, mask, keys, k):
    keys = jnp.where(mask, keys, jnp.inf)
    neg_top, idx = jax.lax.top_k(-keys, k)  # k smallest keys per row
    sel_mask = jnp.isfinite(-neg_top)
    sel = jnp.take_along_axis(nbr, idx, axis=1)
    sel = jnp.where(sel_mask, sel, INVALID)
    return sel, sel_mask, idx
