from repro.core.samplers.base import LayerSample, Sampler, make_sampler
from repro.core.samplers.neighbor import NeighborSampler
from repro.core.samplers.labor import LaborSampler
from repro.core.samplers.random_walk import RandomWalkSampler
from repro.core.samplers.full import FullSampler

__all__ = [
    "LayerSample",
    "Sampler",
    "make_sampler",
    "NeighborSampler",
    "LaborSampler",
    "RandomWalkSampler",
    "FullSampler",
]
