"""LABOR sampling (Balin & Catalyurek, 2023) — A.1.2.

LABOR-0: every vertex ``t`` rolls ONE uniform ``r_t`` shared by all seeds
in the batch; edge ``(t -> s)`` is kept iff ``r_t <= k / d_s``.  Sharing
``r_t`` across seeds is what makes the union of sampled neighborhoods
smaller than NS in expectation — the property Cooperative Minibatching
amplifies (bigger effective batch => more sharing).

LABOR-* (importance variant): keep iff ``r_t <= min(1, c_s * pi_t)`` with
per-seed normalizers ``c_s`` solving ``sum_t min(1, c_s pi_t) = k``
(expected in-edges per seed stays k).  The original paper optimizes
``pi`` globally to minimize E[#sampled vertices]; we use the closed-form
proxy ``pi_t ∝ sqrt(out_degree(t))`` (high-multiplicity sources get
larger inclusion probability, so their single variate is shared by more
seeds) and solve ``c_s`` by vectorized bisection.  This preserves
LABOR-*'s qualitative ordering (fewer unique vertices than LABOR-0,
Fig. 3) and its unbiasedness given ``pi``; documented as an approximation
in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers.base import LayerSample


def importance_probs(graph: Graph) -> jax.Array:
    """pi_t proxy: sqrt of out-degree, normalized to mean 1 (host-side)."""
    out_deg = jnp.zeros((graph.num_vertices,), jnp.float32).at[graph.indices].add(1.0)
    pi = jnp.sqrt(jnp.maximum(out_deg, 1.0))
    return pi / jnp.mean(pi)


@dataclass(frozen=True)
class LaborSampler:
    fanout: int = 10
    importance: bool = False  # False -> LABOR-0, True -> LABOR-*
    backend: str = "reference"  # neighbor_table backend ("reference"|"fused")

    @property
    def name(self) -> str:
        return "labor*" if self.importance else "labor0"

    def row_width(self, graph: Graph) -> int:
        return graph.max_degree

    def sample_layer(
        self, graph: Graph, seeds: jax.Array, rng: DependentRNG, layer: int
    ) -> LayerSample:
        nbr, mask = graph.neighbor_table(seeds, backend=self.backend)
        deg = jnp.sum(mask, axis=1).astype(jnp.float32)
        r = rng.vertex_uniform(nbr, salt=layer)  # shared r_t across the batch
        if not self.importance:
            thresh = jnp.minimum(1.0, self.fanout / jnp.maximum(deg, 1.0))
            accept = r <= thresh[:, None]
        else:
            pi = importance_probs(graph)
            pi_t = pi[jnp.where(nbr == INVALID, 0, nbr)]
            c_s = _solve_cs(pi_t, mask, jnp.float32(self.fanout))
            accept = r <= jnp.minimum(1.0, c_s[:, None] * pi_t)
        accept = accept & mask
        sampled = jnp.where(accept, nbr, INVALID)
        etypes = (
            graph.neighbor_edge_types(seeds) if graph.edge_types is not None else None
        )
        return LayerSample(seeds=seeds, nbr=sampled, mask=accept, etypes=etypes)


@jax.jit
def _solve_cs(pi_t: jax.Array, mask: jax.Array, k) -> jax.Array:
    """Per-row bisection for c_s:  sum_t min(1, c_s*pi_t) = k."""
    pi = jnp.where(mask, pi_t, 0.0)
    deg = jnp.sum(mask, axis=1).astype(jnp.float32)

    def expected(c):
        return jnp.sum(jnp.minimum(1.0, c[:, None] * pi), axis=1)

    lo = jnp.zeros_like(deg)
    hi = jnp.full_like(deg, 1e6)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_small = expected(mid) < k
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(0, 40, body, (lo, hi))
    c = 0.5 * (lo + hi)
    # if d_s <= k the whole neighborhood is kept (threshold 1 for all t)
    return jnp.where(deg <= k, 1e6, c)
