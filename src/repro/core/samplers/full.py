"""Full-neighborhood "sampler" (no sampling; k >= max degree)."""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.core.graph import Graph
from repro.core.rng import DependentRNG
from repro.core.samplers.base import LayerSample


@dataclass(frozen=True)
class FullSampler:
    name: str = "full"
    backend: str = "reference"  # neighbor_table backend ("reference"|"fused")

    def row_width(self, graph: Graph) -> int:
        return graph.max_degree

    def sample_layer(
        self, graph: Graph, seeds: jax.Array, rng: DependentRNG, layer: int
    ) -> LayerSample:
        nbr, mask = graph.neighbor_table(seeds, backend=self.backend)
        etypes = (
            graph.neighbor_edge_types(seeds) if graph.edge_types is not None else None
        )
        return LayerSample(seeds=seeds, nbr=nbr, mask=mask, etypes=etypes)
