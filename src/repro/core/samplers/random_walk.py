"""RandomWalk sampling (PinSAGE; Ying et al., 2018) — A.1.3.

``a`` walks of length ``o`` with restart probability ``p`` from every
seed; the ``k`` most-visited vertices become the seed's sampled
neighborhood.  Equivalent to weighted NS from A_tilde = sum_i A^i without
materializing A_tilde.

TPU adaptation: walks are a ``lax.scan`` over ``o`` steps carrying the
(n, a) walker front; the visit histogram / top-k uses the static-size
``jnp.unique`` + ``top_k`` combination per row.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph, INVALID
from repro.core.rng import DependentRNG
from repro.core.samplers.base import LayerSample


@dataclass(frozen=True)
class RandomWalkSampler:
    fanout: int = 10
    walk_length: int = 3
    restart_prob: float = 0.5
    num_walks: int = 16
    name: str = "rw"
    # Accepted for factory uniformity; the scan-carried walk has no
    # neighbor-table expansion to fuse, so both values run the reference.
    backend: str = "reference"

    def row_width(self, graph: Graph) -> int:
        return self.fanout

    def sample_layer(
        self, graph: Graph, seeds: jax.Array, rng: DependentRNG, layer: int
    ) -> LayerSample:
        z = rng.fold(salt=1000 + layer)
        nbr, mask = _walk_topk(
            graph.indptr,
            graph.indices,
            seeds,
            z,
            self.walk_length,
            self.restart_prob,
            self.num_walks,
            self.fanout,
            graph.num_edges,
        )
        return LayerSample(seeds=seeds, nbr=nbr, mask=mask)


@partial(jax.jit, static_argnums=(4, 5, 6, 7, 8))
def _walk_topk(indptr, indices, seeds, z, o, p, a, k, num_edges):
    from repro.core.rng import hash_u32, uniform_from_u32

    n = seeds.shape[0]
    walk_ids = jnp.arange(n * a, dtype=jnp.int32).reshape(n, a)

    def random_neighbor(cur, salt):
        """One uniform in-neighbor of each walker; INVALID if none/invalid."""
        safe = jnp.where(cur == INVALID, 0, cur)
        offs = indptr[safe]
        deg = indptr[safe + 1] - offs
        u = uniform_from_u32(
            hash_u32(walk_ids, z, salt) ^ hash_u32(cur, z + 7, salt)
        )
        pick = offs + jnp.minimum((u * deg).astype(jnp.int32), jnp.maximum(deg - 1, 0))
        nxt = indices[jnp.clip(pick, 0, max(num_edges - 1, 0))]
        return jnp.where((deg > 0) & (cur != INVALID), nxt, INVALID)

    seeds_b = jnp.broadcast_to(seeds[:, None], (n, a))

    def step(cur, salt):
        restart = (
            uniform_from_u32(hash_u32(walk_ids, z + 13, salt)) < p
        )
        base = jnp.where(restart, seeds_b, cur)
        nxt = random_neighbor(base, salt)
        # dead-end walkers restart from the seed next step
        nxt = jnp.where(nxt == INVALID, seeds_b, nxt)
        return nxt, nxt

    first = random_neighbor(seeds_b, 0)
    first = jnp.where(first == INVALID, seeds_b, first)
    _, visits = jax.lax.scan(step, first, jnp.arange(1, o, dtype=jnp.int32))
    visited = jnp.concatenate([first[None], visits], axis=0)  # (o, n, a)
    visited = jnp.moveaxis(visited, 0, 1).reshape(n, o * a)
    # never count the seed itself as its own neighbor
    visited = jnp.where(visited == seeds[:, None], INVALID, visited)

    def row_topk(row):
        uniq, counts = jnp.unique(
            row, size=o * a, fill_value=INVALID, return_counts=True
        )
        counts = jnp.where(uniq == INVALID, 0, counts)
        top_counts, idx = jax.lax.top_k(counts, k)
        sel = uniq[idx]
        sel_mask = top_counts > 0
        return jnp.where(sel_mask, sel, INVALID), sel_mask

    nbr, mask = jax.vmap(row_topk)(visited)
    valid_seed = (seeds != INVALID)[:, None]
    return jnp.where(valid_seed, nbr, INVALID), mask & valid_seed
