"""Wall-clock timing helpers used by the benchmark harness."""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating timer; use as a context manager around hot regions."""

    name: str = "timer"
    total_s: float = 0.0
    count: int = 0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total_s += time.perf_counter() - self._t0
        self.count += 1

    @property
    def mean_us(self) -> float:
        return 1e6 * self.total_s / max(1, self.count)

    def reset(self) -> None:
        self.total_s = 0.0
        self.count = 0


def bench_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Return mean microseconds per call of ``fn(*args)`` (blocks on jax)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters
