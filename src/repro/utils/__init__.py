from repro.utils.logging import get_logger
from repro.utils.timing import Timer

__all__ = ["get_logger", "Timer"]
