from repro.train.optim import adam_init, adam_update, sgd_update, cosine_lr
from repro.train.loop import TrainConfig, train_gnn
from repro.train.checkpoint import save_checkpoint, load_checkpoint

__all__ = [
    "adam_init",
    "adam_update",
    "sgd_update",
    "cosine_lr",
    "TrainConfig",
    "train_gnn",
    "save_checkpoint",
    "load_checkpoint",
]
