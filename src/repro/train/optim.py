"""Hand-rolled optimizers (optax is unavailable offline).

Adam (Kingma & Ba, 2014) with the paper's default lr=1e-3, plus SGD and a
cosine schedule for the LM pool.  States are plain pytrees so they shard
with pjit like any other array.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def _moment_like(p):
    """f32 moments even for bf16 params (standard mixed-precision Adam)."""
    dtype = jnp.float32 if jnp.issubdtype(p.dtype, jnp.floating) else p.dtype
    return jnp.zeros(p.shape, dtype)


def adam_init(params) -> AdamState:
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(_moment_like, params),
        nu=jax.tree.map(_moment_like, params),
    )


def adam_update(
    params,
    grads,
    state: AdamState,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    step = state.step + 1
    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads
    )
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
        state.nu,
        grads,
    )
    t = step.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1**t)
    nu_hat_scale = 1.0 / (1 - b2**t)

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(u.dtype)
        return (p.astype(u.dtype) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def sgd_update(params, grads, lr: float = 1e-2, momentum_state=None, momentum: float = 0.9):
    if momentum_state is None:
        return jax.tree.map(lambda p, g: p - lr * g, params, grads), None
    new_m = jax.tree.map(lambda m, g: momentum * m + g, momentum_state, grads)
    return jax.tree.map(lambda p, m: p - lr * m, params, new_m), new_m


def cosine_lr(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
