"""Flat-npz pytree checkpointing (orbax is unavailable offline)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, extra: dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(params)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"keys": sorted(flat), "extra": extra or {}}
    with open(os.path.splitext(path)[0] + ".json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, like) -> Any:
    """Restore into the structure of ``like`` (same flattening order)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten_with_paths(like)
    assert sorted(data.files) == sorted(flat_like), "checkpoint structure mismatch"
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_keys, leaf in leaves_paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        new_leaves.append(np.asarray(data[key]).astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
