"""Classification metrics (paper reports F1-scores, Table 3 / Fig 4)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def masked_softmax_xent_parts(logits, labels, valid):
    """(CE sum over valid rows, valid count) — the two pieces a shard_map
    body psums across PEs before dividing, so the distributed loss is the
    same global masked mean the single-device formula computes (up to
    cross-PE float reduction order)."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    ll = jnp.take_along_axis(
        logits - logits.max(-1, keepdims=True), labels[:, None], axis=-1
    )[:, 0]
    ce = logz - ll
    return jnp.sum(jnp.where(valid, ce, 0.0)), jnp.sum(valid)


def masked_softmax_xent(logits, labels, valid):
    """Mean CE over valid rows; logits (n, C), labels (n,), valid (n,)."""
    s, n = masked_softmax_xent_parts(logits, labels, valid)
    return s / jnp.maximum(n, 1)


def micro_f1(preds: np.ndarray, labels: np.ndarray) -> float:
    """Micro-F1 == accuracy for single-label multiclass."""
    preds, labels = np.asarray(preds), np.asarray(labels)
    return float((preds == labels).mean()) if len(preds) else 0.0


def macro_f1(preds: np.ndarray, labels: np.ndarray, num_classes: int) -> float:
    f1s = []
    for c in range(num_classes):
        tp = ((preds == c) & (labels == c)).sum()
        fp = ((preds == c) & (labels != c)).sum()
        fn = ((preds != c) & (labels == c)).sum()
        denom = 2 * tp + fp + fn
        f1s.append(2 * tp / denom if denom else 0.0)
    return float(np.mean(f1s))
