"""GNN training drivers: independent vs cooperative minibatching.

Both drivers run the *same* model code and the same global batch size;
they differ only in how the minibatch plan is built and how embeddings
are provided — exactly the paper's controlled comparison (§4.3, Fig. 9).

* independent: P PEs × local batch b, P separate plans (vmap-stacked),
  gradients averaged across PEs (the standard data-parallel all-reduce).
* cooperative: ONE global batch of size b·P partitioned by ownership,
  all-to-all exchanges during sampling + F/B (Alg. 1), gradients
  averaged across PEs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.cooperative import (
    CoopCapacityPlan,
    SimExecutor,
    build_cooperative_minibatch,
    redistribute,
)
from repro.core.dependent import DependentSchedule
from repro.core.graph import INVALID
from repro.core.minibatch import CapacityPlan, build_minibatch
from repro.core.partition import Partition, make_partition
from repro.core.samplers.base import make_sampler
from repro.models.gnn import GNNConfig, gnn_apply, gnn_apply_cooperative, init_gnn
from repro.train.metrics import masked_softmax_xent, micro_f1
from repro.train.optim import adam_init, adam_update


@dataclass
class TrainConfig:
    mode: str = "cooperative"        # independent | cooperative
    num_pes: int = 4
    local_batch: int = 64            # b; global batch = b * P
    num_steps: int = 100
    lr: float = 1e-3
    sampler: str = "labor0"
    fanout: int = 10
    kappa: Optional[int] = 1         # dependent-minibatching window
    partition: str = "hash"
    seed: int = 0
    eval_every: int = 25


@dataclass
class TrainResult:
    params: dict
    losses: list = field(default_factory=list)
    val_f1: list = field(default_factory=list)


def _owned_train_ids(dataset, part: Partition, num_pes: int) -> list[np.ndarray]:
    owner = np.asarray(part.owner)
    return [dataset.train_ids[owner[dataset.train_ids] == p] for p in range(num_pes)]


def _seed_batches_independent(dataset, step, P, b, seed):
    """P independent local batches (P, b) from the global training set."""
    g = np.random.default_rng(seed + step)
    sel = g.choice(len(dataset.train_ids), size=(P, b), replace=False)
    return dataset.train_ids[sel].astype(np.int32)


def _seed_batches_cooperative(owned_ids, step, P, b, seed):
    """Per-PE owned seed batches (P, b) — union is the global batch."""
    out = np.full((P, b), np.int32(INVALID), np.int32)
    for p in range(P):
        g = np.random.default_rng(seed + step * 131 + p)
        n = min(b, len(owned_ids[p]))
        out[p, :n] = g.choice(owned_ids[p], size=n, replace=False)
    return out


def train_gnn(dataset, gnn_cfg: GNNConfig, tc: TrainConfig) -> TrainResult:
    graph = dataset.graph
    P, b, L = tc.num_pes, tc.local_batch, gnn_cfg.num_layers
    sampler = make_sampler(tc.sampler, fanout=tc.fanout)
    sched = DependentSchedule(base_seed=tc.seed, kappa=tc.kappa)
    features, labels = dataset.features, dataset.labels
    V = graph.num_vertices

    params = init_gnn(jax.random.PRNGKey(tc.seed), gnn_cfg)
    opt = adam_init(params)

    if tc.mode == "cooperative":
        part = make_partition(tc.partition, graph, P, seed=tc.seed)
        owned = _owned_train_ids(dataset, part, P)
        caps = CoopCapacityPlan.geometric(b, L, tc.fanout, V, P)
        ex = SimExecutor(P)

        def loss_fn(params, seeds, step):
            rng = sched.rng_at(0).state_at(step)  # dynamic smoothed-RNG state
            mb = build_cooperative_minibatch(
                graph, sampler, part, seeds, rng, L, caps, ex
            )

            def load(ids):
                h = features[jnp.clip(ids, 0, V - 1)]
                return jnp.where((ids != INVALID)[:, None], h, 0.0)

            H = ex.pe(load, mb.input_ids)  # (P, capL, d)
            logits = gnn_apply_cooperative(
                params, gnn_cfg, ex, mb.layers, H, caps.tilde_caps
            )  # (P, cap0, C)
            seed_ids = mb.seed_ids
            y = labels[jnp.clip(seed_ids, 0, V - 1)]
            valid = seed_ids != INVALID
            return masked_softmax_xent(
                logits.reshape(-1, logits.shape[-1]),
                y.reshape(-1),
                valid.reshape(-1),
            )

        batch_fn = lambda step: _seed_batches_cooperative(owned, step, P, b, tc.seed)
    else:
        caps = CapacityPlan.geometric(b, L, tc.fanout, V)

        def loss_fn(params, seeds, step):
            rng = sched.rng_at(0).state_at(step)  # dynamic smoothed-RNG state

            def one_pe(seeds_p):
                mb = build_minibatch(graph, sampler, seeds_p, rng, L, caps)
                h = features[jnp.clip(mb.input_ids, 0, V - 1)]
                h = jnp.where((mb.input_ids != INVALID)[:, None], h, 0.0)
                logits = gnn_apply(params, gnn_cfg, mb.layers, h)
                y = labels[jnp.clip(mb.seed_ids, 0, V - 1)]
                valid = mb.seed_ids != INVALID
                return masked_softmax_xent(logits, y, valid)

            return jnp.mean(jax.vmap(one_pe)(seeds))

        batch_fn = lambda step: _seed_batches_independent(dataset, step, P, b, tc.seed)

    @partial(jax.jit, static_argnums=())
    def train_step(params, opt, seeds, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, seeds, step)
        params, opt = adam_update(params, grads, opt, lr=tc.lr)
        return params, opt, loss

    result = TrainResult(params=params)
    for step in range(tc.num_steps):
        seeds = jnp.asarray(batch_fn(step))
        # `step` is a dynamic arg: the smoothed-RNG state (z1, z2, c) is
        # computed inside the compiled step, so one trace serves the whole
        # kappa schedule.
        params, opt, loss = train_step(params, opt, seeds, jnp.int32(step))
        result.losses.append(float(loss))
        if tc.eval_every and (step + 1) % tc.eval_every == 0:
            result.val_f1.append(evaluate(dataset, gnn_cfg, params, tc))
        result.params = params
    return result


def evaluate(
    dataset, gnn_cfg: GNNConfig, params, tc: TrainConfig, split: str = "val",
    max_batches: int = 4,
) -> float:
    """Micro-F1 with (independent) sampled neighborhoods — Fig. 4 style."""
    graph = dataset.graph
    V = graph.num_vertices
    sampler = make_sampler(tc.sampler, fanout=tc.fanout)
    caps = CapacityPlan.geometric(tc.local_batch, gnn_cfg.num_layers, tc.fanout, V)
    ids_all = {"val": dataset.val_ids, "test": dataset.test_ids}[split]
    from repro.core.rng import DependentRNG

    preds, ys = [], []
    for i in range(max_batches):
        lo = i * tc.local_batch
        ids = ids_all[lo : lo + tc.local_batch]
        if len(ids) == 0:
            break
        seeds = frontier.pad_to(jnp.asarray(ids, jnp.int32), tc.local_batch)
        rng = DependentRNG(base_seed=tc.seed + 999, kappa=1, step=i)
        mb = build_minibatch(graph, sampler, seeds, rng, gnn_cfg.num_layers, caps)
        h = dataset.features[jnp.clip(mb.input_ids, 0, V - 1)]
        h = jnp.where((mb.input_ids != INVALID)[:, None], h, 0.0)
        logits = gnn_apply(params, gnn_cfg, mb.layers, h)
        valid = np.asarray(mb.seed_ids) != INVALID
        pred = np.asarray(jnp.argmax(logits, -1))[valid]
        y = np.asarray(dataset.labels)[np.asarray(mb.seed_ids)[valid]]
        preds.append(pred)
        ys.append(y)
    return micro_f1(np.concatenate(preds), np.concatenate(ys))
