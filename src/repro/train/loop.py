"""GNN training driver over the unified :class:`MinibatchEngine`.

Both minibatching modes run the *same* model code, the same loss path,
and the same global batch size — exactly the paper's controlled
comparison (§4.3, Fig. 9).  The mode lives entirely inside the engine:

* independent: P PEs × local batch b, P separate plans (vmap-stacked),
  gradients averaged across PEs (the standard data-parallel all-reduce).
* cooperative: ONE global batch of size b·P partitioned by ownership,
  all-to-all exchanges during sampling + F/B (Alg. 1), gradients
  averaged across PEs.

The training step below never branches on the mode: it builds a plan,
gathers input features through it, applies the model through the
engine, and supervises the seed frontier.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.graph import INVALID
from repro.engine import EngineConfig, MinibatchEngine
from repro.models.gnn import GNNConfig, init_gnn
from repro.train.metrics import masked_softmax_xent, micro_f1
from repro.train.optim import adam_init, adam_update


@dataclass
class TrainConfig:
    mode: str = "cooperative"        # independent | cooperative
    num_pes: int = 4
    local_batch: int = 64            # b; global batch = b * P
    num_steps: int = 100
    lr: float = 1e-3
    sampler: str = "labor0"
    fanout: int = 10
    schedule: str = "smoothed"       # iid | smoothed | nested
    kappa: Optional[int] = 1         # dependent-minibatching window
    partition: str = "hash"
    seed: int = 0
    eval_every: int = 25
    plan_backend: str = "reference"  # reference | fused (Pallas on TPU)
    executor: str = "sim"            # sim | shard (real P-device mesh)

    def engine_config(self, num_layers: int) -> EngineConfig:
        return EngineConfig(
            mode=self.mode, num_pes=self.num_pes, local_batch=self.local_batch,
            num_layers=num_layers, sampler=self.sampler, fanout=self.fanout,
            schedule=self.schedule, kappa=self.kappa, partition=self.partition,
            seed=self.seed, plan_backend=self.plan_backend,
            executor=self.executor,
        )


@dataclass
class TrainResult:
    params: dict
    losses: list = field(default_factory=list)
    val_f1: list = field(default_factory=list)


def make_loss_fn(engine: MinibatchEngine, gnn_cfg: GNNConfig, store, labels):
    """Single mode-agnostic loss path: plan -> features -> logits -> xent.

    ``plan_at`` folds the seed draw and schedule RNG into the trace, so
    the whole step is device-resident.  Used by the sim/vmap executors;
    the shard executor's equivalent lives in
    :meth:`repro.engine.shard.ShardRunner.make_loss_and_grad` with the
    same masked-mean semantics.
    """
    V = engine.graph.num_vertices
    labels = jnp.asarray(labels)

    def loss_fn(params, step):
        plan = engine.plan_at(step)
        H = plan.gather_inputs(store)
        logits = engine.apply_model(params, gnn_cfg, plan, H)
        y = labels[jnp.clip(plan.seed_ids, 0, V - 1)]
        valid = plan.seed_ids != INVALID
        return masked_softmax_xent(
            logits.reshape(-1, logits.shape[-1]), y.reshape(-1), valid.reshape(-1)
        )

    return loss_fn


def train_gnn(dataset, gnn_cfg: GNNConfig, tc: TrainConfig) -> TrainResult:
    engine = MinibatchEngine.from_config(
        dataset.graph, tc.engine_config(gnn_cfg.num_layers), dataset=dataset
    )
    store, labels = engine.store, dataset.labels

    params = init_gnn(jax.random.PRNGKey(tc.seed), gnn_cfg)
    opt = adam_init(params)

    if tc.executor == "shard" and tc.mode == "cooperative":
        # real multi-device path: per-PE plan build + cooperative F/B run
        # under shard_map on a P-device mesh, and gradient sync is an
        # explicit jax.lax.psum over the same axis as the all-to-alls
        loss_and_grad = engine.shard_runner.make_loss_and_grad(
            gnn_cfg, store.features, labels
        )
    else:
        loss_and_grad = jax.value_and_grad(
            make_loss_fn(engine, gnn_cfg, store, labels)
        )

    @partial(jax.jit, static_argnums=())
    def train_step(params, opt, step):
        loss, grads = loss_and_grad(params, step)
        params, opt = adam_update(params, grads, opt, lr=tc.lr)
        return params, opt, loss

    result = TrainResult(params=params)
    for step in range(tc.num_steps):
        # `step` is a dynamic arg: seed draw and smoothed-RNG state
        # (z1, z2, c) are computed inside the compiled step, so one trace
        # serves the whole kappa schedule.
        params, opt, loss = train_step(params, opt, jnp.int32(step))
        result.losses.append(float(loss))
        if tc.eval_every and (step + 1) % tc.eval_every == 0:
            result.val_f1.append(evaluate(dataset, gnn_cfg, params, tc))
        result.params = params
    return result


def evaluate(
    dataset, gnn_cfg: GNNConfig, params, tc: TrainConfig, split: str = "val",
    max_batches: int = 4,
) -> float:
    """Micro-F1 with (independent) sampled neighborhoods — Fig. 4 style."""
    eval_engine = MinibatchEngine.from_config(
        dataset.graph,
        EngineConfig(
            mode="independent", num_pes=1, local_batch=tc.local_batch,
            num_layers=gnn_cfg.num_layers, sampler=tc.sampler,
            fanout=tc.fanout, schedule="iid", seed=tc.seed + 999,
        ),
        dataset=dataset,
    )
    ids_all = {"val": dataset.val_ids, "test": dataset.test_ids}[split]
    preds, ys = [], []
    for i in range(max_batches):
        lo = i * tc.local_batch
        ids = ids_all[lo : lo + tc.local_batch]
        if len(ids) == 0:
            break
        seeds = frontier.pad_to(jnp.asarray(ids, jnp.int32), tc.local_batch)
        plan = eval_engine.build_plan(seeds, step=i)  # iid schedule @ seed+999
        h = plan.gather_inputs(eval_engine.store)
        logits = eval_engine.apply_model(params, gnn_cfg, plan, h)
        valid = np.asarray(plan.seed_ids) != INVALID
        pred = np.asarray(jnp.argmax(logits, -1))[valid]
        y = np.asarray(dataset.labels)[np.asarray(plan.seed_ids)[valid]]
        preds.append(pred)
        ys.append(y)
    return micro_f1(np.concatenate(preds), np.concatenate(ys))
