"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's per-minibatch runtime is dominated by (Table 1):

* neighbor aggregation in the forward/backward pass  -> ``spmm``
* vertex-embedding fetch from storage                -> ``gather`` (paged)
* GAT edge softmax (§4.3 GAT experiment)             -> ``seg_softmax``

Plan construction itself (the frontier hot loop behind
``EngineConfig.plan_backend="fused"``) gets three more:

* frontier dedup + rank resolution                   -> ``unique_compact``
* masked CSR neighbor expansion                      -> ``frontier_gather``
* CSR indptr -> per-edge row ids (COO assembly)      -> ``expand_indptr``

Each kernel ships as ``kernel.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper with padding/dispatch) and
``ref.py`` (pure-jnp oracle used by tests and by non-TPU backends).

TPU adaptation (DESIGN.md §3): CUDA GNN kernels use warp-per-row
gather-reduce; here rows are blocked to MXU/VPU-friendly tiles, the
feature dimension is tiled in 128-lane slices, and the embedding-table
gather is re-organised as a *paged* scan (grid over table pages resident
in VMEM, accumulating hits) instead of random HBM access.
"""
from repro.kernels.errors import KernelContractError, require_divisible
from repro.kernels.spmm.ops import spmm_mean, spmm_sum
from repro.kernels.gather.ops import paged_gather
from repro.kernels.seg_softmax.ops import seg_softmax
from repro.kernels.unique_compact.ops import unique_compact, unique_with_inverse
from repro.kernels.frontier_gather.ops import frontier_gather
from repro.kernels.expand_indptr.ops import expand_indptr

__all__ = [
    "spmm_mean", "spmm_sum", "paged_gather", "seg_softmax",
    "unique_compact", "unique_with_inverse", "frontier_gather",
    "expand_indptr",
    "KernelContractError", "require_divisible",
]
