"""Public wrapper for the masked CSR frontier gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.frontier_gather.kernel import frontier_gather_pallas
from repro.kernels.frontier_gather.ref import frontier_gather_ref

_INVALID = np.int32(2**31 - 1)  # numpy: safe to create at import time under a trace


def frontier_gather(
    indptr: jax.Array,   # (V+1,) int32
    indices: jax.Array,  # (E,) int32
    seeds: jax.Array,    # (n,) int32, INVALID padded
    max_degree: int,
    *,
    block_n: int = 256,
    page: int = 2048,
) -> tuple[jax.Array, jax.Array]:
    """(nbr (n, max_degree), mask) — bit-identical to the jnp oracle.

    Dispatches to the paged Pallas sweep on TPU, to the reference
    elsewhere.  Seeds pad with INVALID (contributing all-masked rows)
    and indices pad freely (padded edges are never inside any valid
    ``[offs, offs+deg)`` row slice), so blocking cannot perturb output.
    """
    if jax.default_backend() != "tpu":
        return frontier_gather_ref(indptr, indices, seeds, max_degree)
    (n,) = seeds.shape
    (E,) = indices.shape
    pad_n = (-n) % block_n
    pad_e = (-E) % page
    seeds_p = jnp.pad(seeds, (0, pad_n), constant_values=_INVALID)
    ind_p = jnp.pad(indices, (0, pad_e), constant_values=_INVALID)
    nbr = frontier_gather_pallas(
        indptr, ind_p, seeds_p,
        max_degree=max_degree, block_n=block_n, page=page,
    )[:n]
    mask = nbr != _INVALID
    return nbr, mask
