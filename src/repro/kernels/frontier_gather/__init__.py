from repro.kernels.frontier_gather.ops import frontier_gather
from repro.kernels.frontier_gather.ref import frontier_gather_ref

__all__ = ["frontier_gather", "frontier_gather_ref"]
