"""Pallas TPU kernel: masked CSR frontier gather (neighbor expansion).

Every sampling hop expands a padded seed frontier into its degree-capped
neighbor table ``(n, max_degree)``.  The CSR ``indices`` array lives in
HBM and the row slices each seed needs are scattered across it — the
same DMA-hostile random access as the embedding gather — so this kernel
reuses the paged-sweep structure of ``repro.kernels.gather``:

    grid = (seed blocks, edge pages)

``indptr`` stays VMEM-resident (one int32 per vertex); each step holds
one ``(page,)`` tile of ``indices`` and contributes the neighbor slots
whose global edge index ``indptr[s] + k`` falls inside the current page.
A slot is written by exactly one page; misses contribute INVALID
(int32 max), so a running ``min`` combine is exact, with the customary
``pl.when(p == 0)`` first-visit init.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible

_INVALID = np.int32(2**31 - 1)


def _frontier_kernel(seeds_ref, iptr_ref, ind_ref, out_ref, *,
                     page: int, max_degree: int, block_n: int):
    p = pl.program_id(1)
    seeds = seeds_ref[...]                             # (bn,)
    iptr = iptr_ref[...]                               # (V+1,)
    tile = ind_ref[...]                                # (page,)
    safe = jnp.where(seeds == _INVALID, 0, seeds)
    offs = iptr[safe]
    deg = iptr[safe + 1] - offs
    pos = jax.lax.broadcasted_iota(jnp.int32, (block_n, max_degree), 1)
    edge = offs[:, None] + pos                         # global edge index
    valid = (pos < deg[:, None]) & (seeds != _INVALID)[:, None]
    local = edge - p * page
    hit = valid & (local >= 0) & (local < page)
    vals = tile[jnp.clip(local, 0, page - 1)]
    contrib = jnp.where(hit, vals, _INVALID)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(p != 0)
    def _combine():
        out_ref[...] = jnp.minimum(out_ref[...], contrib)


@functools.partial(
    jax.jit, static_argnames=("max_degree", "block_n", "page", "interpret")
)
def frontier_gather_pallas(
    indptr: jax.Array,   # (V+1,) int32
    indices: jax.Array,  # (E,) int32, E % page == 0
    seeds: jax.Array,    # (n,) int32, n % block_n == 0
    *,
    max_degree: int,
    block_n: int = 256,
    page: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """(n, max_degree) int32 neighbor table, INVALID where padded."""
    (E,) = indices.shape
    (n,) = seeds.shape
    require_divisible("frontier_gather_pallas", [
        ("E", E, "page", page),
        ("n", n, "block_n", block_n),
    ])
    V1 = indptr.shape[0]
    grid = (n // block_n, E // page)
    return pl.pallas_call(
        functools.partial(
            _frontier_kernel, page=page, max_degree=max_degree,
            block_n=block_n,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, p: (i,)),
            pl.BlockSpec((V1,), lambda i, p: (0,)),
            pl.BlockSpec((page,), lambda i, p: (p,)),
        ],
        out_specs=pl.BlockSpec((block_n, max_degree), lambda i, p: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, max_degree), jnp.int32),
        interpret=interpret,
    )(seeds, indptr, indices)
