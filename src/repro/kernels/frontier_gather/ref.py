"""Pure-jnp oracle for the masked CSR frontier gather.

Bit-identical to ``Graph._neighbor_table`` — the padded degree-capped
neighbor-table expansion every sampler starts from.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_INVALID = np.int32(2**31 - 1)  # numpy: safe to create at import time under a trace


@partial(jax.jit, static_argnums=(3,))
def frontier_gather_ref(
    indptr: jax.Array,   # (V+1,) int32 CSR row pointer
    indices: jax.Array,  # (E,) int32 source ids
    seeds: jax.Array,    # (n,) int32 vertex ids, INVALID padded
    max_degree: int,
) -> tuple[jax.Array, jax.Array]:
    """(nbr (n, max_degree) INVALID-padded, mask (n, max_degree))."""
    num_edges = indices.shape[0]
    safe = jnp.where(seeds == _INVALID, 0, seeds)
    offs = indptr[safe]
    deg = indptr[safe + 1] - offs
    pos = jnp.arange(max_degree, dtype=jnp.int32)[None, :]
    idx = jnp.clip(offs[:, None] + pos, 0, max(num_edges - 1, 0))
    nbr = indices[idx]
    mask = (pos < deg[:, None]) & (seeds != _INVALID)[:, None]
    nbr = jnp.where(mask, nbr, _INVALID)
    return nbr, mask
