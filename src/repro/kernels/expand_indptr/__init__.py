from repro.kernels.expand_indptr.ops import expand_indptr
from repro.kernels.expand_indptr.ref import expand_indptr_ref

__all__ = ["expand_indptr", "expand_indptr_ref"]
