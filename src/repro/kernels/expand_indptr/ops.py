"""Public wrapper for CSR indptr expansion."""
from __future__ import annotations

import jax

from repro.kernels.expand_indptr.kernel import expand_indptr_pallas
from repro.kernels.expand_indptr.ref import expand_indptr_ref


def expand_indptr(
    indptr: jax.Array,
    num_edges: int,
    *,
    block_e: int = 512,
) -> jax.Array:
    """(num_edges,) int32 row id per edge slot, -1 past indptr[-1].

    Dispatches to the Pallas kernel on TPU, to the searchsorted oracle
    elsewhere.  ``num_edges`` that is not a block multiple falls back to
    the reference (plan capacities are caller-chosen powers of two, so
    this only triggers for odd ad-hoc shapes).
    """
    if jax.default_backend() != "tpu" or num_edges % block_e != 0:
        return expand_indptr_ref(indptr, num_edges)
    return expand_indptr_pallas(indptr, num_edges, block_e=block_e)
