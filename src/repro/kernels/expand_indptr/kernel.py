"""Pallas TPU kernel: CSR indptr -> per-edge-slot row ids.

Plan-local subgraph assembly (``layer_to_coo``) needs COO row ids for a
capacity-padded edge buffer.  The row of edge slot ``e`` is the number
of indptr entries ``<= e``, minus one — computed here per block via a
``(block_e, R+1)`` comparison matrix against the VMEM-resident indptr
(at most cap+1 int32s).  Each output tile is visited exactly once, so
no cross-step combine is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible


def _expand_kernel(iptr_ref, row_ref, *, block_e: int):
    i = pl.program_id(0)
    iptr = iptr_ref[...]                               # (R+1,)
    pos = jax.lax.broadcasted_iota(jnp.int32, (block_e, 1), 0)[:, 0]
    e = i * block_e + pos                              # global slot ids
    cnt = jnp.sum(iptr[None, :] <= e[:, None], axis=1).astype(jnp.int32)
    row = cnt - 1
    total = iptr[iptr.shape[0] - 1]
    row_ref[...] = jnp.where(e < total, row, -1)


@functools.partial(jax.jit, static_argnames=("num_edges", "block_e", "interpret"))
def expand_indptr_pallas(
    indptr: jax.Array,  # (R+1,) int32 ascending
    num_edges: int,     # output length, % block_e == 0
    *,
    block_e: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(num_edges,) int32 row ids, -1 at or beyond indptr[-1]."""
    require_divisible("expand_indptr_pallas", [
        ("num_edges", num_edges, "block_e", block_e),
    ])
    R1 = indptr.shape[0]
    return pl.pallas_call(
        functools.partial(_expand_kernel, block_e=block_e),
        grid=(num_edges // block_e,),
        in_specs=[pl.BlockSpec((R1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((num_edges,), jnp.int32),
        interpret=interpret,
    )(indptr)
