"""Pure-jnp oracle for CSR indptr expansion (row ids per edge slot)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnums=(1,))
def expand_indptr_ref(indptr: jax.Array, num_edges: int) -> jax.Array:
    """(num_edges,) int32 row id of each edge slot, -1 past indptr[-1].

    ``row[e] = r`` iff ``indptr[r] <= e < indptr[r+1]``; slots at or
    beyond the total edge count ``indptr[-1]`` get -1.
    """
    e = jnp.arange(num_edges, dtype=jnp.int32)
    row = jnp.searchsorted(indptr, e, side="right").astype(jnp.int32) - 1
    return jnp.where(e < indptr[-1], row, -1)
