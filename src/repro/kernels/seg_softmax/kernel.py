"""Pallas TPU kernel: masked per-destination edge softmax (GAT, §4.3).

Each destination row softmaxes over its (padded) sampled-neighbor slots.
Tiling: grid over row blocks; one ``(block_n, w)`` logits tile + mask
tile in VMEM, the reduction runs entirely in-registers on the VPU —
replacing the CUDA segment-scan formulation with a dense masked-row one,
which is the natural TPU shape for static-capacity frontiers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible


def _seg_softmax_kernel(e_ref, mask_ref, out_ref):
    e = e_ref[...]         # (bn, w)
    m = mask_ref[...]      # (bn, w)
    neg = jnp.asarray(-1e9, e.dtype)
    masked = jnp.where(m, e, neg)
    mx = jnp.max(masked, axis=1, keepdims=True)
    ex = jnp.exp(masked - mx)
    ex = jnp.where(m, ex, 0.0)
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
    out_ref[...] = (ex / denom).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def seg_softmax_pallas(
    e: jax.Array,     # (n, w)
    mask: jax.Array,  # (n, w)
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, w = e.shape
    require_divisible("seg_softmax_pallas", [("n", n, "block_n", block_n)])
    grid = (n // block_n,)
    return pl.pallas_call(
        _seg_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), e.dtype),
        interpret=interpret,
    )(e, mask)
