"""Public wrapper for the edge-softmax kernel (multi-head aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.seg_softmax.kernel import seg_softmax_pallas
from repro.kernels.seg_softmax.ref import seg_softmax_ref


def seg_softmax(e: jax.Array, mask: jax.Array, *, block_n: int = 256) -> jax.Array:
    """Masked softmax over neighbor slots; supports (n, w) and (n, w, h)."""
    if jax.default_backend() != "tpu":
        return seg_softmax_ref(e, mask)
    if e.ndim == 3:  # fold heads into rows: (n, w, h) -> (n*h, w)
        n, w, h = e.shape
        e2 = jnp.moveaxis(e, 2, 1).reshape(n * h, w)
        m2 = jnp.repeat(mask, h, axis=0)
        out = seg_softmax(e2, m2, block_n=block_n)
        return jnp.moveaxis(out.reshape(n, h, w), 1, 2)
    n, w = e.shape
    pad_n = (-n) % block_n
    e_p = jnp.pad(e, ((0, pad_n), (0, 0)))
    m_p = jnp.pad(mask, ((0, pad_n), (0, 0)), constant_values=False)
    return seg_softmax_pallas(e_p, m_p, block_n=block_n)[:n]
