from repro.kernels.seg_softmax.ops import seg_softmax

__all__ = ["seg_softmax"]
