"""Pure-jnp oracle for masked per-destination edge softmax (GAT)."""
from __future__ import annotations

import jax.numpy as jnp


def seg_softmax_ref(e: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Softmax over axis 1 restricted to valid slots; invalid -> 0.

    e: (n, w[, h]) attention logits; mask: (n, w).
    """
    m = mask[..., None] if e.ndim == 3 else mask
    neg = jnp.asarray(-1e9, e.dtype)
    masked = jnp.where(m, e, neg)
    mx = jnp.max(masked, axis=1, keepdims=True)
    ex = jnp.exp(masked - mx)
    ex = jnp.where(m, ex, 0.0)
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
    return ex / denom
