"""Typed contract errors for the Pallas kernel layer.

Kernel preconditions used to be bare ``assert`` statements — invisible
under ``python -O`` and silent about *which* shape broke *which* block
constraint.  :class:`KernelContractError` carries the kernel name and
the offending (dimension, value, divisor) triples so a violation names
its fix, and ``repro.analysis`` rule RA005 enforces this style.
"""
from __future__ import annotations

from typing import Sequence, Tuple

#: (dimension name, dimension value, divisor name, divisor value)
Constraint = Tuple[str, int, str, int]


class KernelContractError(ValueError):
    """A Pallas kernel was called with shapes violating its contract."""

    def __init__(self, kernel: str, message: str, values: dict = None):
        self.kernel = kernel
        self.values = dict(values or {})
        detail = ""
        if self.values:
            detail = " (" + ", ".join(
                f"{k}={v}" for k, v in self.values.items()
            ) + ")"
        super().__init__(f"{kernel}: {message}{detail}")


def require_divisible(kernel: str, constraints: Sequence[Constraint]) -> None:
    """Raise :class:`KernelContractError` listing every violated triple.

    Each constraint is ``(dim_name, dim_value, divisor_name, divisor)``
    requiring ``dim_value % divisor == 0``.  All violations are reported
    at once so a caller fixing padding sees the full contract.
    """
    bad = [
        (dn, dv, bn, bv)
        for dn, dv, bn, bv in constraints
        if bv <= 0 or dv % bv != 0
    ]
    if bad:
        values = {}
        for dn, dv, bn, bv in bad:
            values[dn] = int(dv)
            values[bn] = int(bv)
        names = " and ".join(f"{dn} % {bn} != 0" for dn, dv, bn, bv in bad)
        raise KernelContractError(
            kernel,
            f"block divisibility violated: {names}; pad inputs to block "
            "multiples (see kernels/<name>/ops.py for the padding wrapper)",
            values,
        )
