"""Pure-jnp oracle for the masked embedding gather (feature loading)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_INVALID = np.int32(2**31 - 1)  # numpy: safe to create at import time under a trace


def gather_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[ids[i]]; padding ids (INVALID or any id >= V) -> 0."""
    V = table.shape[0]
    valid = (ids >= 0) & (ids < V)
    rows = table[jnp.clip(ids, 0, V - 1)]
    return jnp.where(valid[..., None], rows, 0.0)
