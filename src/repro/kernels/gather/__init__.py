from repro.kernels.gather.ops import paged_gather

__all__ = ["paged_gather"]
