"""Public wrapper for the paged gather kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather.kernel import paged_gather_pallas
from repro.kernels.gather.ref import gather_ref


def paged_gather(
    table: jax.Array,
    ids: jax.Array,
    *,
    block_n: int = 512,
    block_d: int = 128,
    page: int = 2048,
) -> jax.Array:
    """Masked embedding gather; INVALID / out-of-range ids produce zeros."""
    if jax.default_backend() != "tpu":
        return gather_ref(table, ids)
    V, d = table.shape
    n = ids.shape[0]
    pad_v = (-V) % page
    pad_d = (-d) % block_d
    pad_n = (-n) % block_n
    table_p = jnp.pad(table, ((0, pad_v), (0, pad_d)))
    ids_p = jnp.pad(ids, (0, pad_n), constant_values=jnp.int32(2**31 - 1))
    out = paged_gather_pallas(
        table_p, ids_p, block_n=block_n, block_d=block_d, page=page
    )
    return out[:n, :d]
