"""Pallas TPU kernel: paged vertex-embedding gather (feature loading).

The paper's feature-loading stage streams embedding rows from storage
(Table 1, β-bandwidth bound).  Random row access into a huge HBM table
is hostile to the TPU DMA engine, so the table is scanned in *pages*:

    grid = (row blocks, feature blocks, table pages)

Each step holds one ``(page, block_d)`` table tile in VMEM; requested
rows that fall inside the current page are gathered from VMEM and
accumulated into the output tile (revisited across the page axis, which
Pallas keeps innermost so the output tile stays resident).  Cost is one
sequential sweep of the table slice — optimal when the id batch is dense
in the table (the cooperative case: ids are *owned*, hence clustered),
and a documented trade-off vs random access when ids are sparse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible


def _gather_kernel(ids_ref, table_ref, out_ref, *, page: int):
    p = pl.program_id(2)
    ids = ids_ref[...]                      # (bn,)
    tab = table_ref[...]                    # (page, bd)
    local = ids - p * page
    hit = (local >= 0) & (local < page)
    rows = tab[jnp.clip(local, 0, page - 1)]
    contrib = jnp.where(hit[:, None], rows, 0.0).astype(out_ref.dtype)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(p != 0)
    def _acc():
        out_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_d", "page", "interpret")
)
def paged_gather_pallas(
    table: jax.Array,  # (V, d), V % page == 0
    ids: jax.Array,    # (n,) int32, n % block_n == 0
    *,
    block_n: int = 512,
    block_d: int = 128,
    page: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    V, d = table.shape
    (n,) = ids.shape
    require_divisible("paged_gather_pallas", [
        ("V", V, "page", page),
        ("d", d, "block_d", block_d),
        ("n", n, "block_n", block_n),
    ])
    grid = (n // block_n, d // block_d, V // page)
    return pl.pallas_call(
        functools.partial(_gather_kernel, page=page),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j, p: (i,)),
            pl.BlockSpec((page, block_d), lambda i, j, p: (p, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(ids, table)
