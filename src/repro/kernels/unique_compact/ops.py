"""Public wrappers for the fused unique-and-compact frontier op."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.unique_compact.kernel import unique_compact_pallas
from repro.kernels.unique_compact.ref import unique_with_inverse_ref

_INVALID = np.int32(2**31 - 1)  # numpy: safe to create at import time under a trace


def unique_with_inverse(
    ids: jax.Array,
    cap: int,
    *,
    block_m: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """(uniq (cap,), inv (m,)) of a flat int32 id vector.

    ``uniq`` is bit-identical to ``frontier.unique_padded(ids, cap)`` and
    ``inv`` to ``frontier.lookup(uniq, ids)``; INVALID-padding appended
    for blocking cannot perturb either (INVALID sorts last and maps to
    -1).  Dispatches to the Pallas sweep on TPU, to the pure-jnp fused
    oracle elsewhere.
    """
    flat = ids.reshape(-1)
    if jax.default_backend() != "tpu":
        return unique_with_inverse_ref(flat, cap)
    m = flat.shape[0]
    pad = (-m) % block_m
    flat_p = jnp.pad(flat, (0, pad), constant_values=_INVALID)
    order = jnp.argsort(flat_p)
    s = flat_p[order]
    inv_sorted, uniq = unique_compact_pallas(s, cap, block_m=block_m)
    inv = jnp.zeros((m + pad,), jnp.int32).at[order].set(inv_sorted)
    return uniq, inv[:m]


def unique_compact(ids: jax.Array, cap: int, *, block_m: int = 256) -> jax.Array:
    """Sorted unique ids with INVALID padding (fused unique only)."""
    uniq, _ = unique_with_inverse(ids, cap, block_m=block_m)
    return uniq
