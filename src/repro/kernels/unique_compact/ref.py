"""Pure-jnp oracle for the fused unique-and-compact frontier op.

Single-pass replacement for the sort-network pair
``unique_padded(cat, cap)`` + ``lookup(uniq, cat)``: one stable sort of
the concatenated frontier, first-occurrence flags, cumulative ranks, and
two scatters.  Bit-identical to the reference pair:

* ``uniq`` equals ``jnp.unique(cat, size=cap, fill_value=INVALID)`` —
  INVALID participates as an ordinary value that sorts last, and
  overflow keeps the smallest ``cap`` uniques;
* ``inv[j]`` equals ``lookup(uniq, cat[j])`` — the position of ``cat[j]``
  in ``uniq``, or -1 when ``cat[j]`` is INVALID or was dropped by the
  overflow policy (rank >= cap).

Used directly on non-TPU backends and as the test oracle for the Pallas
kernel (`repro.kernels.unique_compact.kernel`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_INVALID = np.int32(2**31 - 1)  # numpy: safe to create at import time under a trace


@partial(jax.jit, static_argnums=(1,))
def unique_with_inverse_ref(ids: jax.Array, cap: int) -> tuple[jax.Array, jax.Array]:
    """(uniq (cap,), inv (m,)) for a flat int32 id vector.

    ``uniq``: sorted unique ids, INVALID-padded, smallest ``cap`` kept on
    overflow.  ``inv``: index of each input in ``uniq``; -1 for INVALID
    inputs and for uniques dropped by the overflow policy.
    """
    flat = ids.reshape(-1)
    m = flat.shape[0]
    order = jnp.argsort(flat)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    rank = (jnp.cumsum(first) - 1).astype(jnp.int32)
    # rank >= cap parks in slot `cap`, sliced off below; all writers of a
    # slot < cap carry the same value, so the duplicate scatter is exact
    slot = jnp.where(rank < cap, rank, cap)
    uniq = jnp.full((cap + 1,), _INVALID, flat.dtype).at[slot].set(s)[:cap]
    inv_sorted = jnp.where((rank < cap) & (s != _INVALID), rank, -1)
    inv = jnp.zeros((m,), jnp.int32).at[order].set(inv_sorted)
    return uniq, inv
