"""Pallas TPU kernel: dedup + compaction of a sorted padded frontier.

Plan construction spends its hot loop deduplicating the concatenated
frontier ``cat = [S^l | sampled neighbors]`` and resolving every element
into the next frontier ``S^{l+1}``.  The sort itself stays in XLA (TPU
sort networks are already optimal there); this kernel fuses everything
downstream of the sort into ONE sequential sweep:

    grid = (m / block_m,)        -- sequential on TPU

Each step consumes one block of the *sorted* ids and carries two scalars
across grid steps in SMEM scratch — the running unique count and the
previous block's last element — so first-occurrence flags and global
unique ranks need no second pass.  Per block it emits

* ``inv``  (blocked): the rank of each element in the unique set, already
  masked to -1 for INVALID ids and for ranks beyond ``cap`` (the
  keep-smallest-``cap`` overflow policy of ``frontier.unique_padded``);
* ``uniq`` (cap-resident, revisited): the compacted unique ids, built via
  a (cap x block_m) equality-match min-combine instead of a dynamic
  scatter — duplicate matches carry equal values, so min is exact.

Replaces a ``jnp.unique`` + two ``searchsorted`` lookups per layer with
one fused pass over already-sorted data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.errors import KernelContractError, require_divisible

_INVALID = np.int32(2**31 - 1)


def _unique_kernel(s_ref, inv_ref, uniq_ref, carry_ref, *, cap: int, block_m: int):
    i = pl.program_id(0)
    s = s_ref[...]                                     # (bm,) sorted ids

    @pl.when(i == 0)
    def _reset_carry():
        carry_ref[0] = 0                               # uniques seen so far
        carry_ref[1] = 0                               # previous last element

    base = carry_ref[0]
    prev = carry_ref[1]

    # first-occurrence flags without adjacent shifts: position j is a
    # first occurrence iff no earlier in-block position holds the same
    # value AND (for j == 0 semantics) the value differs from the carry.
    jj = jax.lax.broadcasted_iota(jnp.int32, (block_m, block_m), 0)
    kk = jax.lax.broadcasted_iota(jnp.int32, (block_m, block_m), 1)
    same_earlier = jnp.any((s[None, :] == s[:, None]) & (kk < jj), axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)[:, 0]
    carries_over = (i > 0) & (s == prev)
    first = ~same_earlier & ~jnp.where(pos == 0, carries_over, False)

    # global rank via an in-block inclusive prefix sum (triangular mask)
    local = jnp.sum(first[None, :] & (kk <= jj), axis=1).astype(jnp.int32)
    rank = base + local - 1                            # (bm,)

    inv_ref[...] = jnp.where((rank < cap) & (s != _INVALID), rank, -1)

    # compacted uniques: slot c takes the (unique) value whose rank is c
    cc = jax.lax.broadcasted_iota(jnp.int32, (cap, block_m), 0)
    match = rank[None, :] == cc                        # (cap, bm)
    contrib = jnp.min(jnp.where(match, s[None, :], _INVALID), axis=1)

    @pl.when(i == 0)
    def _init():
        uniq_ref[...] = contrib

    @pl.when(i != 0)
    def _combine():
        uniq_ref[...] = jnp.minimum(uniq_ref[...], contrib)

    carry_ref[0] = base + jnp.sum(first).astype(jnp.int32)
    carry_ref[1] = s[block_m - 1]


@functools.partial(
    jax.jit, static_argnames=("cap", "block_m", "interpret")
)
def unique_compact_pallas(
    sorted_ids: jax.Array,  # (m,) int32 ASCENDING, m % block_m == 0
    cap: int,
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(inv (m,), uniq (cap,)) — see module docstring for semantics."""
    (m,) = sorted_ids.shape
    require_divisible("unique_compact_pallas", [
        ("m", m, "block_m", block_m),
    ])
    if cap < 1:
        raise KernelContractError(
            "unique_compact_pallas", "cap must be >= 1", {"cap": cap}
        )
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_unique_kernel, cap=cap, block_m=block_m),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((cap,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(sorted_ids)
