from repro.kernels.unique_compact.ops import unique_compact, unique_with_inverse
from repro.kernels.unique_compact.ref import unique_with_inverse_ref

__all__ = ["unique_compact", "unique_with_inverse", "unique_with_inverse_ref"]
