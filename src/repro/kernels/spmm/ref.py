"""Pure-jnp oracle for padded-bipartite neighbor aggregation."""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ref(
    src: jnp.ndarray,      # (S, d) source embeddings
    nbr_idx: jnp.ndarray,  # (n, w) row indices into src, -1 = padding
    mask: jnp.ndarray,     # (n, w)
    mean: bool = True,
) -> jnp.ndarray:
    rows = src[jnp.clip(nbr_idx, 0)]
    rows = jnp.where(mask[..., None], rows, 0.0)
    s = jnp.sum(rows, axis=1)
    if not mean:
        return s
    deg = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1)
    return s / deg.astype(s.dtype)
