"""Public jit'd wrappers for the SpMM kernel (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spmm.kernel import spmm_pallas
from repro.kernels.spmm.ref import spmm_ref


def _pad_axis(x, axis, mult, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _spmm(src, nbr_idx, mask, mean: bool, block_n=128, block_d=128):
    """Pad to block multiples, run the kernel, slice back."""
    if jax.default_backend() != "tpu":
        # CPU/GPU: interpret-mode Pallas is the correctness path but slow;
        # production non-TPU backends use the jnp oracle (same math).
        return spmm_ref(src, nbr_idx, mask, mean=mean)
    n, d = nbr_idx.shape[0], src.shape[1]
    src_p = _pad_axis(src, 1, block_d)
    idx_p = _pad_axis(nbr_idx, 0, block_n, value=-1)
    mask_p = _pad_axis(mask, 0, block_n, value=False)
    out = spmm_pallas(
        src_p, idx_p, mask_p, mean=mean, block_n=block_n, block_d=block_d
    )
    return out[:n, :d]


def spmm_mean(src, nbr_idx, mask, **kw):
    """Masked mean aggregation over sampled neighbors."""
    return _spmm(src, nbr_idx, mask, mean=True, **kw)


def spmm_sum(src, nbr_idx, mask, **kw):
    """Masked sum aggregation over sampled neighbors."""
    return _spmm(src, nbr_idx, mask, mean=False, **kw)
