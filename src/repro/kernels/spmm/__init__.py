from repro.kernels.spmm.ops import spmm_mean, spmm_sum

__all__ = ["spmm_mean", "spmm_sum"]
