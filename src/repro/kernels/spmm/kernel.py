"""Pallas TPU kernel: padded-bipartite neighbor aggregation (SpMM).

Tiling: grid = (row blocks, feature blocks).  The destination tile
``(block_n, block_d)`` lives in VMEM; the *source* matrix is tiled along
the feature dimension only — one ``(S, block_d)`` slice per grid column —
so the per-step VMEM working set is

    S*block_d*4  +  block_n*w*(4+1)  +  block_n*block_d*4   bytes,

which for the production caps (S <= 8192, block_d = 128) is ~4.2 MB,
inside the 16 MB v5e VMEM budget.  Row gathers then hit VMEM, not HBM —
the TPU-native replacement for CUDA warp-per-row gathers (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible


def _spmm_kernel(src_ref, idx_ref, mask_ref, out_ref, *, mean: bool):
    src = src_ref[...]          # (S, bd) feature slice, VMEM resident
    idx = idx_ref[...]          # (bn, w)
    msk = mask_ref[...]         # (bn, w)
    bn, w = idx.shape
    rows = src[jnp.clip(idx.reshape(-1), 0, src.shape[0] - 1)]
    rows = rows.reshape(bn, w, -1)
    rows = jnp.where(msk[..., None], rows, 0.0)
    acc = jnp.sum(rows, axis=1)
    if mean:
        deg = jnp.maximum(jnp.sum(msk, axis=1, keepdims=True), 1)
        acc = acc / deg.astype(acc.dtype)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mean", "block_n", "block_d", "interpret")
)
def spmm_pallas(
    src: jax.Array,
    nbr_idx: jax.Array,
    mask: jax.Array,
    *,
    mean: bool = True,
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(S, d) x (n, w) -> (n, d); shapes must be pre-padded to blocks."""
    S, d = src.shape
    n, w = nbr_idx.shape
    require_divisible("spmm_pallas", [
        ("n", n, "block_n", block_n),
        ("d", d, "block_d", block_d),
    ])
    grid = (n // block_n, d // block_d)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, mean=mean),
        grid=grid,
        in_specs=[
            pl.BlockSpec((S, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), src.dtype),
        interpret=interpret,
    )(src, nbr_idx, mask)
