"""Device-resident set-associative CLOCK cache (batched, pure jnp).

True LRU is host-side control flow (an ordered dict), so it cannot live
on the accelerator; ``repro.core.cache.LRUCache`` stays the *oracle*.
This module is the device policy the paper's §4.2 bandwidth numbers need
on a real hot path: a set-associative cache with per-set CLOCK
(second-chance) eviction whose lookup *and* eviction are jittable array
ops — no host branching, no data-dependent shapes.

Layout: ``capacity = num_sets * ways`` slots per PE.  A vertex id hashes
to one set (Knuth multiplicative hash); within the set, ways are managed
by a clock hand over reference bits.  A batch access:

1. dedups the batch (``jnp.unique`` with static ``size``),
2. probes all ids against the tag array in one shot
   (:func:`repro.store.kernel.tag_probe` — Pallas on TPU),
3. sets the reference bit of every hit,
4. inserts misses round-by-round (at most one insert per set per round,
   ``ways`` rounds total — a static Python loop), each round running
   CLOCK victim selection *vectorized across all sets*.

Per-PE states carry a leading ``(P, ...)`` axis so cooperative mode's
owned-vertex caches (`CooperativeCacheArray` semantics, §4.3.1) are the
same arrays with P > 1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import INVALID
from repro.store.kernel import tag_probe

_HASH_MULT = np.uint32(2654435761)  # Knuth multiplicative hashing


class ClockState(NamedTuple):
    """Per-PE cache state; every leaf has a leading ``(P, ...)`` axis."""

    tags: jax.Array       # (P, S, W) int32 resident vertex id, INVALID = empty
    ref: jax.Array        # (P, S, W) bool CLOCK reference bits
    hand: jax.Array       # (P, S) int32 clock hand per set
    hits: jax.Array       # (P,) int32
    misses: jax.Array     # (P,) int32
    requested: jax.Array  # (P,) int32 unique valid ids seen (count_fetched)


class ClockAccess(NamedTuple):
    """Per-unique-id outcome of one batched access."""

    uniq: jax.Array       # (P, n) sorted unique ids, INVALID-padded
    hit: jax.Array        # (P, n) bool — resident before this batch
    slot: jax.Array       # (P, n) int32 flat slot of hits, -1 otherwise
    fill_slot: jax.Array  # (P, n) int32 slot a missed row was admitted to,
                          #         -1 if dropped (set conflict overflow)


def clock_init(capacity: int, ways: int = 8, num_pes: int = 1) -> ClockState:
    """Empty cache of ``capacity`` rows per PE, ``capacity % ways == 0``."""
    if ways < 1 or capacity < ways:
        raise ValueError(f"need capacity >= ways >= 1, got {capacity}/{ways}")
    if capacity % ways:
        raise ValueError(f"capacity {capacity} not a multiple of ways {ways}")
    S = capacity // ways
    P = num_pes
    return ClockState(
        tags=jnp.full((P, S, ways), INVALID, jnp.int32),
        ref=jnp.zeros((P, S, ways), bool),
        hand=jnp.zeros((P, S), jnp.int32),
        hits=jnp.zeros((P,), jnp.int32),
        misses=jnp.zeros((P,), jnp.int32),
        requested=jnp.zeros((P,), jnp.int32),
    )


def hash_set(ids: jax.Array, num_sets: int) -> jax.Array:
    """Multiplicative hash of vertex ids onto ``[0, num_sets)``."""
    h = (ids.astype(jnp.uint32) * _HASH_MULT) >> 8
    return (h % jnp.uint32(num_sets)).astype(jnp.int32)


def unique_rows(ids: jax.Array) -> jax.Array:
    """Row-wise sorted unique with static width (INVALID pads sort last)."""
    n = ids.shape[-1]
    uniq = lambda row: jnp.unique(row, size=n, fill_value=INVALID)
    return jax.vmap(uniq)(ids)


def _insert_one(tags, ref, hand, ids, sets, hit, way):
    """Insert this batch's misses into one PE's cache (CLOCK eviction).

    ``ids`` is one deduplicated row; at most one insert lands per set per
    round, so ``ways`` rounds admit every miss that can fit.  Overflowing
    conflicts (more misses than ways hashing to one set) are dropped —
    they stay misses and their rows are served straight from the fetch.
    """
    S, W = tags.shape
    n = ids.shape[0]
    valid = ids != INVALID
    miss = valid & ~hit

    # second-chance bit for every hit
    way0 = jnp.maximum(way, 0)
    ref = ref.at[sets, way0].max(hit)

    # rank of each miss within its set: argsort by set, then position
    # since the start of the equal-set run
    key = jnp.where(miss, sets, S)
    order = jnp.argsort(key)
    skey = key[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    newseg = jnp.concatenate([jnp.ones((1,), bool), skey[1:] != skey[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(newseg, idx, 0)
    )
    rank = jnp.zeros(n, jnp.int32).at[order].set(idx - seg_start)

    fill_slot = jnp.full(n, -1, jnp.int32)
    wpos = jnp.arange(W, dtype=jnp.int32)
    for r in range(W):
        sel = miss & (rank == r)
        tgt = jnp.where(sel, sets, S)  # out-of-bounds rows are dropped
        ins = jnp.full((S,), INVALID, jnp.int32).at[tgt].set(ids, mode="drop")
        do = ins != INVALID                                    # (S,)
        # CLOCK sweep, vectorized over sets: walk ways from the hand,
        # victim = first clear ref bit; if all set, clear the full circle
        # and take the hand position (classic second chance).
        ordered = (hand[:, None] + wpos[None, :]) % W          # (S, W)
        ref_ord = jnp.take_along_axis(ref, ordered, axis=1)
        k = jnp.argmin(ref_ord, axis=1)
        swept = (wpos[None, :] < k[:, None]) | ref_ord.all(1)[:, None]
        ref_ord = ref_ord & ~swept
        inv = (wpos[None, :] - hand[:, None]) % W
        ref_nat = jnp.take_along_axis(ref_ord, inv, axis=1)
        victim = jnp.take_along_axis(ordered, k[:, None], axis=1)[:, 0]
        at_victim = wpos[None, :] == victim[:, None]
        tags = jnp.where(do[:, None] & at_victim, ins[:, None], tags)
        ref = jnp.where(do[:, None], jnp.where(at_victim, True, ref_nat), ref)
        hand = jnp.where(do, (victim + 1) % W, hand)
        fill_slot = jnp.where(sel, sets * W + victim[sets], fill_slot)

    # a later round may have evicted an earlier same-batch insert (only
    # possible at W == 1): an admitted row owns its slot only if its tag
    # survived to the end of the batch
    survived = tags.reshape(-1)[jnp.maximum(fill_slot, 0)] == ids
    fill_slot = jnp.where((fill_slot >= 0) & survived, fill_slot, -1)
    return tags, ref, hand, fill_slot, miss


@jax.jit
def clock_access(
    state: ClockState, uniq: jax.Array
) -> tuple[ClockState, ClockAccess]:
    """Access one deduplicated batch per PE; returns the new state.

    ``uniq``: (P, n) row-wise *unique* sorted ids (see :func:`unique_rows`),
    INVALID-padded.  Lookup resolves against the pre-batch tags (batched
    semantics: a row evicted by this batch's own inserts still counts as
    the hit it was when the batch arrived).
    """
    P, S, W = state.tags.shape
    valid = uniq != INVALID
    sets = jnp.where(valid, hash_set(uniq, S), 0)
    # one flat probe for all PEs: offset each PE's sets into a (P*S, W)
    # tag view so the Pallas kernel runs once, unbatched
    gsets = sets + jnp.arange(P, dtype=jnp.int32)[:, None] * S
    pids = jnp.where(valid, uniq, -1)  # -1 never matches a resident tag
    way = tag_probe(
        state.tags.reshape(P * S, W), gsets.reshape(-1), pids.reshape(-1)
    ).reshape(P, -1)
    hit = way >= 0
    slot = jnp.where(hit, sets * W + jnp.maximum(way, 0), -1)

    tags, ref, hand, fill_slot, miss = jax.vmap(_insert_one)(
        state.tags, state.ref, state.hand, uniq, sets, hit, way
    )
    new = ClockState(
        tags=tags, ref=ref, hand=hand,
        hits=state.hits + hit.sum(1, dtype=jnp.int32),
        misses=state.misses + miss.sum(1, dtype=jnp.int32),
        requested=state.requested + valid.sum(1, dtype=jnp.int32),
    )
    return new, ClockAccess(uniq=uniq, hit=hit, slot=slot, fill_slot=fill_slot)


class ClockCache:
    """Stateful replay wrapper mirroring ``LRUCache.access_batch``.

    Tracks only tags/ref/hand/counters (no feature rows) so differential
    tests and benchmarks can replay id traces through the device policy
    and compare hit rates against the exact LRU oracle.  ``num_pes > 1``
    mirrors ``CooperativeCacheArray``: row p of an access touches only
    PE p's cache.
    """

    def __init__(self, capacity: int, ways: int = 8, num_pes: int = 1):
        self.capacity = capacity
        self.ways = ways
        self.num_pes = num_pes
        self.state = clock_init(capacity, ways, num_pes)

    def access_batch(self, ids) -> int:
        """Access the unique valid ids of one batch; returns #misses."""
        ids = jnp.asarray(ids, jnp.int32)
        if self.num_pes == 1:
            ids = ids.reshape(1, -1)
        elif ids.ndim != 2 or ids.shape[0] != self.num_pes:
            raise ValueError(
                f"expected (P={self.num_pes}, n) ids, got {ids.shape}"
            )
        before = self.state.misses
        self.state, _ = clock_access(self.state, unique_rows(ids))
        return int((self.state.misses - before).sum())

    # cooperative-parity alias (CooperativeCacheArray.access)
    access = access_batch

    @property
    def hits(self) -> int:
        return int(self.state.hits.sum())

    @property
    def misses(self) -> int:
        return int(self.state.misses.sum())

    @property
    def miss_rate(self) -> float:
        total = self.hits + self.misses
        return self.misses / total if total else 0.0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        z = jnp.zeros((self.num_pes,), jnp.int32)
        self.state = self.state._replace(hits=z, misses=z, requested=z)
