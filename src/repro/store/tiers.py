"""Tiered feature store: device CLOCK cache over a host-memory tier.

``FeatureStore`` keeps every feature row in one device array — fine for
synthetic graphs, impossible for the paper's billion-edge regime.  The
tiered store keeps the full table in *host* memory (the pinned-RAM tier;
an on-disk tier would hang off the same fetch hook) and serves the hot
path from a device-resident CLOCK cache (`repro.store.clock`):

    gather(ids):
      1. dedup ids per PE (device),
      2. probe + CLOCK-update the cache (device, one fused jit),
      3. fetch only the *missed* unique rows from the host tier,
      4. assemble the output from cache hits + fresh fetches and admit
         the fetched rows into their slots (device).

Hit rows are read out of the cache data array *before* the new rows are
scattered in, so a slot recycled within the same batch still serves the
value it held at lookup time — output is bit-exact with the uncached
``FeatureStore.gather`` in every mode.

Accounting matches ``FeatureStore.count_fetched``: ``requested`` counts
unique valid ids per PE-batch (exactly what ``count_fetched`` returns),
``hits + misses == requested``, and ``fetched_rows`` (host counter) is
the rows that actually crossed the host->device link — the β-bandwidth
quantity of Table 1 that κ-scheduled dependent batches shrink (Fig. 5).

Per-PE caches make the cooperative story concrete: with ownership
partitioning upstream (the engine's cooperative seed rows), each PE only
ever asks for *owned* vertices, so the P caches hold disjoint id sets —
the "effectively P-fold global cache" of §4.3.1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import INVALID
from repro.store.clock import ClockState, clock_access, clock_init, unique_rows

_INVALID_NP = np.int32(INVALID)


@jax.jit
def _assemble(data, acc, fetched, ids):
    """Combine cache hits + host fetches into the output; admit fetches.

    ``data``: (P, slots, d) cache rows.  ``fetched``: (P, n, d) host rows
    aligned with ``acc.uniq`` (zeros at hits/padding).  Returns the
    gathered (P, n_ids, d) output and the updated data tier.
    """
    P, nslots, d = data.shape
    n = acc.uniq.shape[1]
    # read hit rows BEFORE admitting this batch's fetches: a slot being
    # recycled in this batch must serve its lookup-time value
    cached = jax.vmap(lambda dp, s: dp[jnp.maximum(s, 0)])(data, acc.slot)
    uniq_rows_ = jnp.where(acc.hit[..., None], cached, fetched)
    tgt = jnp.where(acc.fill_slot >= 0, acc.fill_slot, nslots)
    data = jax.vmap(lambda dp, t, r: dp.at[t].set(r, mode="drop"))(
        data, tgt, fetched
    )
    # route every original id (duplicates included) to its unique row
    pos = jax.vmap(jnp.searchsorted)(acc.uniq, ids)
    out = jnp.take_along_axis(
        uniq_rows_, jnp.clip(pos, 0, n - 1)[..., None], axis=1
    )
    out = jnp.where((ids != INVALID)[..., None], out, 0.0)
    return out, data


class TieredFeatureStore:
    """Device CLOCK cache (tier 0) in front of a host feature table (tier 1).

    Drop-in for ``FeatureStore.gather`` on the engine's hot path: same
    masking semantics (INVALID rows come back as zeros), bit-exact rows,
    plus hit/miss/fetch accounting.  ``capacity`` and the cache state are
    *per PE*; pass ``num_pes > 1`` for stacked ``(P, n)`` id batches.
    """

    def __init__(
        self,
        features,
        capacity: int,
        ways: int = 8,
        num_pes: int = 1,
    ):
        self.host = np.asarray(features)  # host-memory tier, never on device
        if self.host.ndim != 2:
            raise ValueError(f"features must be (V, d), got {self.host.shape}")
        self.capacity = capacity
        self.ways = ways
        self.num_pes = num_pes
        self.state: ClockState = clock_init(capacity, ways, num_pes)
        d = self.host.shape[1]
        self.data = jnp.zeros((num_pes, capacity, d), self.host.dtype)
        self.fetched_rows = 0  # rows pulled across the host->device link
        self.batches = 0

    # -- FeatureStore-compatible surface -----------------------------------
    def gather(self, ids) -> jax.Array:
        """Masked gather through the cache; INVALID rows come back zero."""
        ids_np = np.asarray(ids)
        squeeze = ids_np.ndim == 1
        if squeeze:
            ids_np = ids_np[None]
        if ids_np.ndim != 2 or ids_np.shape[0] != self.num_pes:
            raise ValueError(
                f"expected ({self.num_pes}, n) ids, got shape {ids_np.shape}"
            )
        ids_j = jnp.asarray(ids_np, jnp.int32)
        self.state, acc = clock_access(self.state, unique_rows(ids_j))

        # slow tier: fetch only the missed unique rows (host-side gather —
        # this is the prefetch/dispatch path, not jitted device code)
        uniq_np = np.asarray(acc.uniq)
        missed = (uniq_np != _INVALID_NP) & ~np.asarray(acc.hit)
        V = self.host.shape[0]
        fetched = np.zeros(uniq_np.shape + (self.host.shape[1],), self.host.dtype)
        safe = np.clip(uniq_np, 0, V - 1)
        fetched[missed] = self.host[safe[missed]]
        self.fetched_rows += int(missed.sum())
        self.batches += 1

        out, self.data = _assemble(
            self.data, acc, jnp.asarray(fetched), ids_j
        )
        return out[0] if squeeze else out

    # -- accounting ---------------------------------------------------------
    @property
    def hits(self) -> int:
        return int(self.state.hits.sum())

    @property
    def misses(self) -> int:
        return int(self.state.misses.sum())

    @property
    def requested(self) -> int:
        """Unique valid ids requested — ``FeatureStore.count_fetched`` sums."""
        return int(self.state.requested.sum())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def miss_rate(self) -> float:
        total = self.hits + self.misses
        return self.misses / total if total else 0.0

    def reset_stats(self) -> None:
        z = jnp.zeros((self.num_pes,), jnp.int32)
        self.state = self.state._replace(hits=z, misses=z, requested=z)
        self.fetched_rows = 0
        self.batches = 0
