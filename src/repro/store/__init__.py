"""Tiered feature store: device CLOCK cache over a host feature tier.

`repro.core.cache` keeps the *oracle* (exact host-side LRU, Fig. 5
simulator); this package is the cache that actually serves features on
the hot path — batched set-associative CLOCK lookup/eviction as device
array ops, a host-memory slow tier, and fetch accounting compatible with
``FeatureStore.count_fetched``.
"""
from repro.store.clock import (
    ClockAccess,
    ClockCache,
    ClockState,
    clock_access,
    clock_init,
    hash_set,
    unique_rows,
)
from repro.store.kernel import probe_ref, tag_probe, tag_probe_pallas
from repro.store.tiers import TieredFeatureStore

__all__ = [
    "ClockAccess",
    "ClockCache",
    "ClockState",
    "TieredFeatureStore",
    "clock_access",
    "clock_init",
    "hash_set",
    "probe_ref",
    "tag_probe",
    "tag_probe_pallas",
    "unique_rows",
]
