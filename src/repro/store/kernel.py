"""Pallas TPU kernel: batched set-associative tag probe (cache lookup).

The device CLOCK cache (`repro.store.clock`) resolves a batch of vertex
ids against a tag array ``tags[set, way]`` in one shot: for each id we
need the way whose tag equals it, or -1 on a miss.  Random row access
into the tag array is the same DMA-hostile pattern as the embedding
gather, so the kernel reuses the paged-sweep structure of
``repro.kernels.gather``:

    grid = (id blocks, tag pages)

Each step holds one ``(page, W)`` tag tile in VMEM; ids whose set index
falls inside the current page are resolved there, and results combine
across pages with ``max`` (a miss is -1 everywhere; the owning page
contributes the only way >= 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.errors import require_divisible


def probe_ref(tags: jax.Array, sets: jax.Array, ids: jax.Array) -> jax.Array:
    """Pure-jnp oracle: way of ``ids[i]`` in ``tags[sets[i]]``, -1 on miss.

    Callers must pre-mask padding ids to a value that can never appear
    as a tag (the CLOCK layer uses -1; tags hold vertex ids >= 0 or the
    INVALID empty sentinel).
    """
    rows = tags[sets]                               # (n, W)
    eq = rows == ids[:, None]
    return jnp.where(eq.any(1), jnp.argmax(eq, 1), -1).astype(jnp.int32)


def _probe_kernel(sets_ref, ids_ref, tags_ref, out_ref, *, page: int):
    p = pl.program_id(1)
    sets = sets_ref[...]                            # (bn,)
    ids = ids_ref[...]                              # (bn,)
    tab = tags_ref[...]                             # (page, W)
    local = sets - p * page
    inpage = (local >= 0) & (local < page)
    rows = tab[jnp.clip(local, 0, page - 1)]        # (bn, W)
    eq = rows == ids[:, None]
    way = jnp.where(
        eq.any(1) & inpage, jnp.argmax(eq, axis=1), -1
    ).astype(jnp.int32)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = way

    @pl.when(p != 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], way)


@functools.partial(jax.jit, static_argnames=("block_n", "page", "interpret"))
def tag_probe_pallas(
    tags: jax.Array,   # (S, W) int32, S % page == 0
    sets: jax.Array,   # (n,) int32 set index per id, n % block_n == 0
    ids: jax.Array,    # (n,) int32 probe ids (padding pre-masked to -1)
    *,
    block_n: int = 512,
    page: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    S, W = tags.shape
    (n,) = ids.shape
    require_divisible("tag_probe_pallas", [
        ("S", S, "page", page),
        ("n", n, "block_n", block_n),
    ])
    if sets.shape != (n,):
        raise ValueError(f"sets shape {sets.shape} != ids shape {(n,)}")
    grid = (n // block_n, S // page)
    return pl.pallas_call(
        functools.partial(_probe_kernel, page=page),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, p: (i,)),
            pl.BlockSpec((block_n,), lambda i, p: (i,)),
            pl.BlockSpec((page, W), lambda i, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, p: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(sets, ids, tags)


def tag_probe(
    tags: jax.Array,
    sets: jax.Array,
    ids: jax.Array,
    *,
    block_n: int = 512,
    page: int = 1024,
) -> jax.Array:
    """Batched cache-tag probe; dispatches to the kernel on TPU."""
    if jax.default_backend() != "tpu":
        return probe_ref(tags, sets, ids)
    S, W = tags.shape
    (n,) = ids.shape
    pad_s = (-S) % page
    pad_n = (-n) % block_n
    tags_p = jnp.pad(tags, ((0, pad_s), (0, 0)), constant_values=jnp.int32(-2))
    sets_p = jnp.pad(sets, (0, pad_n))
    ids_p = jnp.pad(ids, (0, pad_n), constant_values=jnp.int32(-1))
    out = tag_probe_pallas(tags_p, sets_p, ids_p, block_n=block_n, page=page)
    return out[:n]
