"""Cooperative inference serving: coalesce live ego-network requests
into shared minibatch plans.

    from repro.data.recsys import make_recsys
    from repro.serve import GNNServer, ServeConfig, poisson_trace

    ds = make_recsys()
    server = GNNServer(ds.graph, ds.features, gnn_cfg, params,
                       ServeConfig(policy="hybrid", max_batch=64))
    report = server.serve_trace(
        poisson_trace(500, rate_rps=2000, seed_pool=ds.user_ids))
    print(report.summary())

Layer map: ``queue`` (arrival traces + FIFO queue), ``coalesce``
(admission policies, bucket ladder, seed merging, retrace guard),
``server`` (the plan/gather/forward loop with latency + fetch
accounting).  See docs/serving.md.
"""
from repro.serve.coalesce import (
    POLICIES,
    BucketedJit,
    BucketLadder,
    CoalescedBatch,
    Coalescer,
    HybridPolicy,
    MaxBatchPolicy,
    MaxWaitPolicy,
    RetraceError,
    make_policy,
)
from repro.serve.queue import (
    Request,
    RequestQueue,
    bursty_trace,
    make_trace,
    poisson_trace,
)
from repro.serve.server import (
    BatchRecord,
    GNNServer,
    ServeConfig,
    ServedRequest,
    ServeReport,
)

__all__ = [
    "BatchRecord",
    "BucketLadder",
    "BucketedJit",
    "CoalescedBatch",
    "Coalescer",
    "GNNServer",
    "HybridPolicy",
    "MaxBatchPolicy",
    "MaxWaitPolicy",
    "POLICIES",
    "Request",
    "RequestQueue",
    "RetraceError",
    "ServeConfig",
    "ServeReport",
    "ServedRequest",
    "bursty_trace",
    "make_policy",
    "make_trace",
    "poisson_trace",
]
