"""Live ego-network request traffic: arrival traces + the FIFO queue.

A *request* is one user's ego-network query — a seed vertex to be scored
by the GNN under the server's fixed (num_layers, fanout) spec — with an
arrival timestamp and a latency deadline (SLO).  Traces are generated
up front with seeded NumPy RNGs so every serving experiment is
bit-reproducible: arrivals are Poisson (exponential gaps) or bursty
(compound Poisson — geometric-size bursts at Poisson epochs, same mean
offered load), and seeds are drawn Zipf-skewed from the query population
so concurrent requests overlap the way real traffic does (hot users /
repeat queries).

Time is *virtual* (seconds since trace start).  The server advances its
own clock as it serves batches, which keeps every admission decision —
and therefore every reported metric — deterministic given the trace.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Request:
    """One ego-network query: score ``seed`` under the server's fanout spec."""

    rid: int                # unique, ordered by arrival
    seed: int               # seed vertex id (e.g. a user in RecsysDataset)
    t_arrival: float        # virtual seconds since trace start
    deadline_ms: float      # latency SLO for this request


def _draw_seeds(
    rng: np.random.Generator, num: int, seed_pool, zipf_a: float
) -> np.ndarray:
    """Zipf-skewed draw over a permuted ranking of ``seed_pool``."""
    pool = np.asarray(seed_pool)
    ranked = rng.permutation(len(pool))
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64) ** (-zipf_a)
    p = ranks / ranks.sum()
    return pool[ranked[rng.choice(len(pool), size=num, p=p)]]


def poisson_trace(
    num_requests: int,
    rate_rps: float,
    seed_pool,
    zipf_a: float = 1.1,
    deadline_ms: float = 50.0,
    seed: int = 0,
) -> list[Request]:
    """Poisson arrivals at ``rate_rps`` requests per virtual second."""
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, num_requests)
    times = np.cumsum(gaps)
    seeds = _draw_seeds(rng, num_requests, seed_pool, zipf_a)
    return [
        Request(rid=i, seed=int(seeds[i]), t_arrival=float(times[i]),
                deadline_ms=deadline_ms)
        for i in range(num_requests)
    ]


def bursty_trace(
    num_requests: int,
    rate_rps: float,
    seed_pool,
    mean_burst: float = 4.0,
    zipf_a: float = 1.1,
    deadline_ms: float = 50.0,
    seed: int = 0,
) -> list[Request]:
    """Compound-Poisson arrivals: geometric bursts at Poisson epochs.

    Burst epochs arrive at ``rate_rps / mean_burst`` so the mean offered
    load matches :func:`poisson_trace` at the same ``rate_rps``; every
    request in a burst shares the epoch timestamp.
    """
    if mean_burst < 1:
        raise ValueError("mean_burst must be >= 1")
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    epoch_rate = rate_rps / mean_burst
    while len(times) < num_requests:
        t += float(rng.exponential(1.0 / epoch_rate))
        size = int(rng.geometric(1.0 / mean_burst))
        times.extend([t] * min(size, num_requests - len(times)))
    seeds = _draw_seeds(rng, num_requests, seed_pool, zipf_a)
    return [
        Request(rid=i, seed=int(seeds[i]), t_arrival=times[i],
                deadline_ms=deadline_ms)
        for i in range(num_requests)
    ]


def make_trace(kind: str, *args, **kwargs) -> list[Request]:
    """Factory: ``"poisson"`` | ``"bursty"``."""
    if kind == "poisson":
        return poisson_trace(*args, **kwargs)
    if kind == "bursty":
        return bursty_trace(*args, **kwargs)
    raise ValueError(f"unknown arrival process {kind!r}")


class RequestQueue:
    """FIFO view over a finite arrival trace.

    The trace is known up front (closed-loop simulation), so admission
    policies may look at *future* arrival times (e.g. "when does the
    B-th next request land?") — the virtual-clock equivalent of blocking
    on the request socket until the batch fills.
    """

    def __init__(self, trace: list[Request]):
        self._trace = sorted(trace, key=lambda r: (r.t_arrival, r.rid))
        self._i = 0

    def __len__(self) -> int:
        return len(self._trace) - self._i

    @property
    def pending(self) -> bool:
        return self._i < len(self._trace)

    def peek_time(self) -> float:
        """Arrival time of the oldest undelivered request."""
        if not self.pending:
            raise IndexError("queue exhausted")
        return self._trace[self._i].t_arrival

    def arrival_time(self, k: int) -> float:
        """Arrival time of the k-th next pending request (0-indexed)."""
        if self._i + k >= len(self._trace):
            raise IndexError(f"only {len(self)} requests pending")
        return self._trace[self._i + k].t_arrival

    def take(self, n: int) -> list[Request]:
        """Pop the ``n`` oldest pending requests (FIFO)."""
        n = min(n, len(self))
        out = self._trace[self._i : self._i + n]
        self._i += n
        return out

    def take_until(self, t: float, limit: int) -> list[Request]:
        """Pop the oldest requests with ``t_arrival <= t``, at most ``limit``."""
        out = []
        while self.pending and len(out) < limit and self.peek_time() <= t:
            out.append(self._trace[self._i])
            self._i += 1
        return out
