"""Coalescing: admission policies, the bucket ladder, and the merger.

The serving claim is the paper's concavity argument (Thm 3.2) applied to
inference: the sampled subgraph of a merged seed set is strictly smaller
than the union of per-request subgraphs, so waiting a little to batch
requests buys bandwidth and compute.  Three pluggable admission policies
trade that batching gain against queueing delay:

* ``max_batch``  — dispatch as soon as B requests are waiting (batch-
  optimal, unbounded wait at low load);
* ``max_wait_ms`` — dispatch when the oldest waiting request has aged w
  milliseconds (latency-bounded, small batches at low load);
* ``hybrid``     — whichever of the two fires first (the usual serving
  compromise).

Merged seed sets are padded to a static *bucket ladder* so the jitted
serving step compiles once per bucket and never again —
:class:`BucketedJit` turns a second trace for the same bucket into a
hard :class:`RetraceError`, and ``repro.analysis`` re-verifies the hot
path with its trace-hygiene harness.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional

import numpy as np

from repro.core.graph import INVALID
from repro.engine import EngineConfig, MinibatchEngine
from repro.serve.queue import Request, RequestQueue


# --------------------------------------------------------------------------
# bucket ladder
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class BucketLadder:
    """Sorted static seed-capacity buckets the jitted step compiles for."""

    buckets: tuple[int, ...]

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(
                f"buckets must be sorted unique, got {self.buckets}"
            )
        if self.buckets[0] < 1:
            raise ValueError("bucket sizes must be >= 1")

    @classmethod
    def geometric(cls, max_batch: int, min_bucket: int = 8) -> "BucketLadder":
        """Doubling ladder ``min_bucket, 2*min_bucket, ..., >= max_batch``."""
        buckets = [min_bucket]
        while buckets[-1] < max_batch:
            buckets.append(buckets[-1] * 2)
        return cls(tuple(buckets))

    @property
    def cap(self) -> int:
        """Largest bucket — the admission cap for any single batch."""
        return self.buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest bucket holding ``n`` seeds."""
        if n > self.cap:
            raise ValueError(f"{n} seeds exceed the ladder cap {self.cap}")
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError  # unreachable: n <= self.cap == buckets[-1]


# --------------------------------------------------------------------------
# admission policies
# --------------------------------------------------------------------------
class MaxBatchPolicy:
    """Dispatch as soon as ``max_batch`` requests are waiting.

    With fewer than ``max_batch`` requests left in the whole trace, the
    remainder flushes at the final arrival (a real server would flush on
    stream close).
    """

    name = "max_batch"

    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch

    def admit(self, queue: RequestQueue, now: float):
        n = len(queue)
        if n >= self.max_batch:
            t = max(now, queue.arrival_time(self.max_batch - 1))
            return queue.take(self.max_batch), t
        t = max(now, queue.arrival_time(n - 1))
        return queue.take(n), t


class MaxWaitPolicy:
    """Dispatch when the oldest waiting request has aged ``max_wait_ms``.

    Everything that arrived by the close time rides along, capped at the
    ladder's largest bucket (``cap`` is stamped by the server).
    """

    name = "max_wait_ms"

    def __init__(self, max_wait_ms: float, cap: int = 1 << 30):
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.max_wait_ms = max_wait_ms
        self.cap = cap

    def admit(self, queue: RequestQueue, now: float):
        t_first = queue.peek_time()
        t_close = max(now, t_first + self.max_wait_ms / 1e3)
        reqs = queue.take_until(t_close, self.cap)
        return reqs, t_close


class HybridPolicy:
    """Dispatch at whichever fires first: batch full or oldest aged out."""

    name = "hybrid"

    def __init__(self, max_batch: int, max_wait_ms: float):
        if max_batch < 1 or max_wait_ms < 0:
            raise ValueError("need max_batch >= 1 and max_wait_ms >= 0")
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms

    def admit(self, queue: RequestQueue, now: float):
        t_first = queue.peek_time()
        t_wait = max(now, t_first + self.max_wait_ms / 1e3)
        if len(queue) >= self.max_batch:
            t_full = max(now, queue.arrival_time(self.max_batch - 1))
            if t_full <= t_wait:
                return queue.take(self.max_batch), t_full
        reqs = queue.take_until(t_wait, self.max_batch)
        return reqs, t_wait


POLICIES = ("max_batch", "max_wait_ms", "hybrid")


def make_policy(name: str, max_batch: int, max_wait_ms: float):
    """Factory over :data:`POLICIES`; ``max_batch`` doubles as the cap."""
    if name == "max_batch":
        return MaxBatchPolicy(max_batch)
    if name == "max_wait_ms":
        return MaxWaitPolicy(max_wait_ms, cap=max_batch)
    if name == "hybrid":
        return HybridPolicy(max_batch, max_wait_ms)
    raise ValueError(f"unknown admission policy {name!r}; one of {POLICIES}")


# --------------------------------------------------------------------------
# retrace guard
# --------------------------------------------------------------------------
class RetraceError(RuntimeError):
    """The jitted serving step traced the same bucket twice — a shape/
    weak-type hygiene bug that would silently recompile in production."""


class BucketedJit:
    """``jax.jit`` wrapper with an observable compiles-per-bucket counter.

    ``bucket_of(*args)`` maps a call to its ladder bucket (from static
    shapes, so it also works on tracers).  The wrapped function legally
    compiles once per distinct bucket; a second trace for a bucket it
    has already compiled raises :class:`RetraceError` at trace time.
    """

    def __init__(self, fn: Callable, bucket_of: Callable, name: str = "step"):
        import jax

        self.name = name
        self.compiles: dict[int, int] = {}

        def counted(*args):
            b = bucket_of(*args)
            self.compiles[b] = self.compiles.get(b, 0) + 1
            if self.compiles[b] > 1:
                raise RetraceError(
                    f"{name}: bucket {b} traced {self.compiles[b]} times — "
                    "the serving step must compile at most once per bucket"
                )
            return fn(*args)

        self._jitted = jax.jit(counted)

    def __call__(self, *args):
        return self._jitted(*args)

    def assert_compiled_once_per_bucket(self) -> None:
        bad = {b: n for b, n in self.compiles.items() if n > 1}
        if bad:
            raise RetraceError(f"{self.name}: retraced buckets {bad}")


# --------------------------------------------------------------------------
# the coalescer
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class CoalescedBatch:
    """One admitted batch: its requests and the padded, deduplicated seeds."""

    requests: tuple[Request, ...]
    seeds: np.ndarray          # (bucket,) int32, sorted unique + INVALID pad
    bucket: int
    t_dispatch: float

    @property
    def num_unique(self) -> int:
        return int((self.seeds != INVALID).sum())


class Coalescer:
    """Merges admitted requests into one shared minibatch plan.

    Seeds dedup into a sorted set, pad to the smallest ladder bucket,
    and build through ``MinibatchEngine.build_plan`` — one lazily
    constructed engine per bucket (static capacities scale with the
    bucket), all sharing the server's graph, sampler spec, and RNG seed
    so a vertex's sampled ego-network is bit-identical across buckets,
    policies, and batch compositions (hash-keyed per-vertex sampling).
    """

    def __init__(
        self,
        graph,
        base_config: EngineConfig,
        ladder: BucketLadder,
    ):
        self.graph = graph
        self.ladder = ladder
        self.base_config = replace(
            base_config, mode="independent", num_pes=1, schedule="iid",
        )
        # eager: engines must exist before the jitted step traces (engine
        # construction runs host-side graph validation that cannot see
        # tracers), and capacities are static per bucket anyway
        self._engines = {
            b: MinibatchEngine.from_config(
                graph, replace(self.base_config, local_batch=b)
            )
            for b in ladder.buckets
        }

    def engine_for(self, bucket: int) -> MinibatchEngine:
        return self._engines[bucket]

    def coalesce(
        self, requests: list[Request], t_dispatch: float
    ) -> CoalescedBatch:
        if not requests:
            raise ValueError("cannot coalesce an empty request set")
        uniq = np.unique(
            np.asarray([r.seed for r in requests], np.int32)
        )
        bucket = self.ladder.bucket_for(len(uniq))
        seeds = np.full((bucket,), INVALID, np.int32)
        seeds[: len(uniq)] = uniq
        return CoalescedBatch(
            requests=tuple(requests), seeds=seeds, bucket=bucket,
            t_dispatch=t_dispatch,
        )

    def build_plan(self, batch: CoalescedBatch):
        """Eager plan build (tests/baselines); the server jits this path."""
        eng = self.engine_for(batch.bucket)
        return eng.build_plan(batch.seeds, step=0)
