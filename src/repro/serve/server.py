"""The serving loop: coalesce -> build_plan -> gather -> forward -> scatter.

``GNNServer`` turns the training reproduction into a traffic-serving
system.  It owns ONE :class:`repro.store.tiers.TieredFeatureStore` that
stays warm across consecutive coalesced batches — the dependent-
minibatch reuse argument (§4.2) applied to traffic: live request streams
are highly dependent (hot users, overlapping ego-nets), so the device
CLOCK cache keeps absorbing fetches batch after batch.

Clocking: arrivals carry *virtual* timestamps (see ``repro.serve.queue``)
and the server advances its clock by a per-batch **service time**.  With
``service_model="modeled"`` (default) that time comes from the paper's
Table-1 bandwidth model (fixed overhead + fetched-bytes/β + flops/γ) so
the whole simulation — admissions, latencies, SLO attainment — is
deterministic and CI-gateable; ``"measured"`` uses real wall-clock of
the executed batch instead.  Real compute runs either way: predictions
are actual GNN forwards, bit-identical to per-request execution.

Bit-identity contract: samplers draw per-vertex hash randomness and the
row-wise forward touches only a vertex's own sampled subtree, so a
seed's prediction does not depend on which batch (or bucket) served it.
``serve_independent`` replays the same trace one request at a time and
is the baseline for the fetched-rows reduction ≥ the concavity gain.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.feature_loader import FeatureStore
from repro.core.graph import INVALID
from repro.engine import EngineConfig
from repro.models.gnn import GNNConfig, gnn_apply
from repro.serve.coalesce import (
    BucketedJit,
    BucketLadder,
    CoalescedBatch,
    Coalescer,
    make_policy,
)
from repro.serve.queue import Request, RequestQueue

SERVICE_MODELS = ("modeled", "measured")


@dataclass(frozen=True)
class ServeConfig:
    """Everything that fixes a serving deployment (workload comes per-trace)."""

    num_layers: int = 2
    fanout: int = 5
    sampler: str = "labor0"
    seed: int = 0
    plan_backend: str = "reference"
    # admission / bucketing
    policy: str = "hybrid"            # max_batch | max_wait_ms | hybrid
    max_batch: int = 64               # admission cap == ladder top
    max_wait_ms: float = 20.0
    min_bucket: int = 8
    deadline_ms: float = 50.0         # default SLO stamped on traces
    # feature tier
    use_cache: bool = True
    cache_capacity: Optional[int] = None   # rows; None -> V // 4
    cache_ways: int = 8
    # virtual-clock service model (Table 1 constants; see docs/serving.md)
    service_model: str = "modeled"    # modeled | measured
    service_fixed_us: float = 150.0   # dispatch + kernel-launch overhead
    service_beta: float = 8e9         # host->device feature bytes/s
    service_gamma: float = 2e12       # effective train-free flop/s

    def __post_init__(self):
        if self.service_model not in SERVICE_MODELS:
            raise ValueError(
                f"service_model must be one of {SERVICE_MODELS}, "
                f"got {self.service_model!r}"
            )
        if self.min_bucket > self.max_batch:
            raise ValueError("min_bucket must be <= max_batch")


@dataclass(frozen=True)
class ServedRequest:
    """Per-request accounting: which batch served it and when."""

    request: Request
    t_dispatch: float
    t_complete: float
    batch_index: int
    bucket: int
    pred: np.ndarray          # (num_classes,) seed logits

    @property
    def latency_ms(self) -> float:
        return 1e3 * (self.t_complete - self.request.t_arrival)

    @property
    def met_deadline(self) -> bool:
        return self.latency_ms <= self.request.deadline_ms


@dataclass(frozen=True)
class BatchRecord:
    """Per-batch accounting row."""

    index: int
    bucket: int
    num_requests: int
    num_unique: int
    t_dispatch: float
    service_ms: float         # virtual-clock service time
    wall_ms: float            # measured compute wall time (informational)
    fetched_rows: int         # host->device rows this batch pulled
    edges: int                # sampled edges across layers


@dataclass
class ServeReport:
    """Outcome of serving one trace: per-request + per-batch accounting."""

    served: list[ServedRequest] = field(default_factory=list)
    batches: list[BatchRecord] = field(default_factory=list)
    fetched_rows: int = 0
    requested_rows: int = 0
    cache_hits: int = 0
    compiles: dict = field(default_factory=dict)

    def latencies_ms(self) -> np.ndarray:
        return np.asarray([s.latency_ms for s in self.served])

    def percentile_ms(self, q: float) -> float:
        return float(np.percentile(self.latencies_ms(), q))

    @property
    def slo_attainment(self) -> float:
        if not self.served:
            return 1.0
        return float(np.mean([s.met_deadline for s in self.served]))

    @property
    def throughput_rps(self) -> float:
        if not self.served:
            return 0.0
        t0 = min(s.request.t_arrival for s in self.served)
        t1 = max(s.t_complete for s in self.served)
        return len(self.served) / max(t1 - t0, 1e-9)

    def summary(self) -> dict:
        return {
            "requests": len(self.served),
            "batches": len(self.batches),
            "p50_ms": round(self.percentile_ms(50), 3),
            "p95_ms": round(self.percentile_ms(95), 3),
            "p99_ms": round(self.percentile_ms(99), 3),
            "slo_attainment": round(self.slo_attainment, 4),
            "throughput_rps": round(self.throughput_rps, 2),
            "fetched_rows": self.fetched_rows,
            "requested_rows": self.requested_rows,
            "mean_batch": round(
                float(np.mean([b.num_requests for b in self.batches])), 2
            ) if self.batches else 0.0,
        }


class GNNServer:
    """Coalescing inference server over one graph + model + feature tier."""

    def __init__(
        self,
        graph,
        features,
        gnn_cfg: GNNConfig,
        params: dict,
        cfg: ServeConfig = ServeConfig(),
    ):
        from repro.store.tiers import TieredFeatureStore

        self.graph = graph
        self.gnn_cfg = gnn_cfg
        self.params = params
        self.cfg = cfg
        self.ladder = BucketLadder.geometric(cfg.max_batch, cfg.min_bucket)
        base = EngineConfig(
            mode="independent", num_pes=1, local_batch=cfg.max_batch,
            num_layers=cfg.num_layers, sampler=cfg.sampler,
            fanout=cfg.fanout, seed=cfg.seed, plan_backend=cfg.plan_backend,
        )
        self.coalescer = Coalescer(graph, base, self.ladder)
        self.store = FeatureStore(features)   # uncached device oracle
        self.tiered = None
        if cfg.use_cache:
            cap = cfg.cache_capacity
            if cap is None:
                cap = max(cfg.cache_ways, graph.num_vertices // 4)
            cap -= cap % cfg.cache_ways
            self.tiered = TieredFeatureStore(
                np.asarray(features), capacity=cap, ways=cfg.cache_ways,
            )
        self._plan = BucketedJit(
            self._build_plan, lambda seeds: seeds.shape[0], "serve.plan"
        )
        self._forward = BucketedJit(
            self._apply, lambda plan, H: plan.seed_ids.shape[0],
            "serve.forward",
        )

    # -- jitted pieces ------------------------------------------------------
    def _build_plan(self, seeds):
        eng = self.coalescer.engine_for(seeds.shape[0])
        return eng.build_plan(seeds, rng=eng.rng_at(0))

    def _apply(self, plan, H):
        return gnn_apply(self.params, self.gnn_cfg, plan.layers, H)

    def hot_path(self, seeds):
        """The full jit-able serving step (plan -> gather -> forward).

        Registered as a ``repro.analysis`` trace entry: one compilation
        must serve every same-bucket call.  The production loop splits
        this at the gather so the tiered store's host fill can run
        between the two jitted halves.
        """
        eng = self.coalescer.engine_for(seeds.shape[0])
        plan = eng.build_plan(seeds, rng=eng.rng_at(0))
        H = self.store.gather(plan.input_ids)
        return plan.seed_ids, gnn_apply(
            self.params, self.gnn_cfg, plan.layers, H
        )

    # -- one batch ----------------------------------------------------------
    def _execute(self, batch: CoalescedBatch, index: int):
        """Run one coalesced batch; returns (record, seed_ids, logits)."""
        import jax
        import jax.numpy as jnp

        fetched_before = self.tiered.fetched_rows if self.tiered else 0
        t0 = time.perf_counter()
        plan = self._plan(jnp.asarray(batch.seeds))
        if self.tiered is not None:
            H = self.tiered.gather(plan.input_ids)
        else:
            H = self.store.gather(plan.input_ids)
        logits = self._forward(plan, H)
        jax.block_until_ready(logits)
        wall_ms = 1e3 * (time.perf_counter() - t0)

        stats = plan.stats()
        edges = sum(stats[f"E{l}"] for l in range(self.cfg.num_layers))
        if self.tiered is not None:
            fetched = self.tiered.fetched_rows - fetched_before
        else:
            fetched = self.store.count_fetched(np.asarray(plan.input_ids))
        service_ms = (
            wall_ms if self.cfg.service_model == "measured"
            else self._modeled_ms(fetched, edges)
        )
        rec = BatchRecord(
            index=index, bucket=batch.bucket,
            num_requests=len(batch.requests), num_unique=batch.num_unique,
            t_dispatch=batch.t_dispatch, service_ms=service_ms,
            wall_ms=wall_ms, fetched_rows=fetched, edges=edges,
        )
        return rec, np.asarray(plan.seed_ids), np.asarray(logits)

    def _modeled_ms(self, fetched_rows: int, edges: int) -> float:
        cfg, d = self.cfg, self.gnn_cfg.in_dim
        load_s = fetched_rows * d * 4 / cfg.service_beta
        flops = 2.0 * edges * d * self.gnn_cfg.hidden_dim
        return 1e3 * (cfg.service_fixed_us * 1e-6 + load_s
                      + flops / cfg.service_gamma)

    # -- trace loops --------------------------------------------------------
    def serve_trace(self, trace: list[Request]) -> ServeReport:
        """Serve a whole arrival trace under the configured policy."""
        policy = make_policy(
            self.cfg.policy, self.cfg.max_batch, self.cfg.max_wait_ms
        )
        queue = RequestQueue(trace)
        report = ServeReport()
        now = 0.0
        while queue.pending:
            reqs, t_disp = policy.admit(queue, now)
            batch = self.coalescer.coalesce(reqs, t_disp)
            rec, seed_ids, logits = self._execute(batch, len(report.batches))
            t_done = t_disp + rec.service_ms / 1e3
            report.batches.append(rec)
            for r in batch.requests:
                pos = int(np.searchsorted(seed_ids, r.seed))
                report.served.append(ServedRequest(
                    request=r, t_dispatch=t_disp, t_complete=t_done,
                    batch_index=rec.index, bucket=rec.bucket,
                    pred=logits[pos],
                ))
            now = t_done
        self._finalize(report)
        return report

    def serve_independent(self, trace: list[Request]) -> ServeReport:
        """Per-request baseline: same trace, every request its own batch.

        FIFO service at the smallest bucket — what a server without
        coalescing pays.  Uses the same cache configuration (fresh
        state), so the fetched-rows comparison isolates coalescing.
        """
        queue = RequestQueue(trace)
        report = ServeReport()
        now = 0.0
        while queue.pending:
            now = max(now, queue.peek_time())
            (req,) = queue.take(1)
            batch = self.coalescer.coalesce([req], now)
            rec, seed_ids, logits = self._execute(batch, len(report.batches))
            t_done = now + rec.service_ms / 1e3
            report.batches.append(rec)
            pos = int(np.searchsorted(seed_ids, req.seed))
            report.served.append(ServedRequest(
                request=req, t_dispatch=now, t_complete=t_done,
                batch_index=rec.index, bucket=rec.bucket, pred=logits[pos],
            ))
            now = t_done
        self._finalize(report)
        return report

    def _finalize(self, report: ServeReport) -> None:
        if self.tiered is not None:
            report.fetched_rows = self.tiered.fetched_rows
            report.requested_rows = self.tiered.requested
            report.cache_hits = self.tiered.hits
        else:
            report.fetched_rows = sum(b.fetched_rows for b in report.batches)
            report.requested_rows = report.fetched_rows
        report.compiles = {
            "serve.plan": dict(self._plan.compiles),
            "serve.forward": dict(self._forward.compiles),
        }
        self._plan.assert_compiled_once_per_bucket()
        self._forward.assert_compiled_once_per_bucket()

    def reset(self) -> None:
        """Fresh cache + counters (keeps compiled steps warm)."""
        if self.tiered is not None:
            from repro.store.tiers import TieredFeatureStore

            self.tiered = TieredFeatureStore(
                self.tiered.host, capacity=self.tiered.capacity,
                ways=self.tiered.ways,
            )
