"""Serve live GNN ego-network traffic with cooperative coalescing.

    PYTHONPATH=src python examples/serve_gnn.py [--smoke]

The graph-side sibling of ``serve_lm.py``: a synthetic user-item
recommendation graph (power-law degrees on both sides) takes a Poisson
stream of user ego-network queries; the server coalesces concurrent
requests into ONE shared minibatch plan per dispatch (the paper's
concavity argument applied to inference), gathers features through the
warm device cache, and scatters per-request predictions back out with
latency accounting.  Prints the policy comparison against the
independent per-request baseline.
"""
import argparse

import jax

from repro.data.recsys import make_recsys
from repro.models.gnn import GNNConfig, init_gnn
from repro.serve import GNNServer, ServeConfig, poisson_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=4000.0)
    args = ap.parse_args()

    if args.smoke:
        ds = make_recsys(num_users=512, num_items=256, edges_per_user=6,
                         feature_dim=32, seed=0)
        requests, hidden = min(args.requests, 80), 64
    else:
        ds = make_recsys(num_users=4096, num_items=1024, seed=0)
        requests, hidden = args.requests, 128

    gnn = GNNConfig(model="gcn", num_layers=2, in_dim=ds.feature_dim,
                    hidden_dim=hidden, num_classes=ds.num_classes)
    params = init_gnn(jax.random.PRNGKey(0), gnn)
    trace = poisson_trace(requests, rate_rps=args.rate,
                          seed_pool=ds.user_ids, seed=1)
    print(f"graph: |V|={ds.graph.num_vertices} |E|={ds.graph.num_edges} "
          f"({ds.num_users} users / {ds.num_items} items)")
    print(f"trace: {requests} requests @ {args.rate:.0f} req/s\n")

    base = ServeConfig(num_layers=2, fanout=5, max_batch=64,
                       max_wait_ms=10.0, use_cache=False)
    indep = GNNServer(ds.graph, ds.features, gnn, params, base)
    rep_i = indep.serve_independent(trace)
    print(f"independent per-request : {rep_i.summary()}")

    ref = None
    for policy in ("max_batch", "max_wait_ms", "hybrid"):
        import dataclasses

        server = GNNServer(ds.graph, ds.features, gnn, params,
                           dataclasses.replace(base, policy=policy))
        rep = server.serve_trace(trace)
        print(f"coalesced [{policy:<11}]: {rep.summary()}")
        print(f"  fetch reduction vs independent: "
              f"{rep_i.fetched_rows / rep.fetched_rows:.2f}x, "
              f"compiles per bucket: {rep.compiles['serve.forward']}")
        if ref is None:
            ref = {s.request.rid: s.pred for s in rep.served}

    # predictions are bit-identical to per-request inference
    import numpy as np

    ok = all(np.array_equal(ref[s.request.rid], s.pred) for s in rep_i.served)
    print(f"\ncoalesced == per-request predictions (bit-identical): {ok}")


if __name__ == "__main__":
    main()
