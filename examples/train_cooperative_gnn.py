"""End-to-end driver: cooperative + dependent GNN training to convergence.

    PYTHONPATH=src python examples/train_cooperative_gnn.py [--steps 300]

The paper's kind is minibatch GNN *training*, where models are small
(~1-3M params; the scale lives in the graph) — this driver trains the
paper's 3-layer GCN (hidden 256) on a 16k-vertex synthetic power-law
graph for a few hundred steps with cooperative minibatching (P=4 PEs)
and dependent batches (smoothed kappa=16 by default, ``--schedule
nested`` for §3.2 nesting), evaluating micro-F1 on the validation
split, with checkpointing.  All plan construction goes through the
unified ``MinibatchEngine`` inside ``train_gnn`` — switch
``--mode independent`` and nothing else changes.
"""
import argparse
import time

import numpy as np

from repro.data import rmat_graph
from repro.data.synthetic import SyntheticGraphDataset
from repro.models.gnn import GNNConfig
from repro.train.checkpoint import save_checkpoint
from repro.train.loop import TrainConfig, evaluate, train_gnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mode", default="cooperative",
                    choices=["cooperative", "independent"])
    ap.add_argument("--pes", type=int, default=4)
    ap.add_argument("--schedule", default="smoothed",
                    choices=["iid", "smoothed", "nested"])
    ap.add_argument("--kappa", type=int, default=16)
    ap.add_argument("--sampler", default="labor0")
    ap.add_argument("--plan-backend", default="reference",
                    choices=["reference", "fused"],
                    help="frontier lowering: jnp algebra or fused Pallas "
                         "kernels (bit-identical plans)")
    ap.add_argument("--out", default="/tmp/coop_gnn_ckpt")
    args = ap.parse_args()

    graph = rmat_graph(scale=14, edge_factor=8, max_degree=32, seed=0)
    ds = SyntheticGraphDataset(graph, feature_dim=64, num_classes=16, seed=0)
    cfg = GNNConfig(model="gcn", num_layers=3, in_dim=64, hidden_dim=256,
                    num_classes=16)
    tc = TrainConfig(
        mode=args.mode, num_pes=args.pes, local_batch=64,
        num_steps=args.steps, fanout=10, schedule=args.schedule,
        kappa=args.kappa, sampler=args.sampler,
        plan_backend=args.plan_backend,
        eval_every=max(args.steps // 6, 1),
    )
    t0 = time.time()
    result = train_gnn(ds, cfg, tc)
    dt = time.time() - t0
    test_f1 = evaluate(ds, cfg, result.params, tc, split="test")
    print(f"steps={args.steps}  time={dt:.1f}s  "
          f"loss {result.losses[0]:.3f}->{np.mean(result.losses[-10:]):.3f}")
    print(f"val F1 trajectory: {[round(f, 3) for f in result.val_f1]}")
    print(f"test F1: {test_f1:.3f}")
    save_checkpoint(args.out, result.params, extra={"steps": args.steps})
    print(f"checkpoint saved to {args.out}.npz")


if __name__ == "__main__":
    main()
