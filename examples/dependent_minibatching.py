"""Dependent minibatching demo: cache locality vs kappa (paper §4.2).

    PYTHONPATH=src python examples/dependent_minibatching.py

Shows the smoothed-RNG mechanism (A.7) directly — per-vertex variates
drift slowly within a kappa window — and the resulting LRU miss-rate
drop for vertex-embedding fetches.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.cache import LRUCache
from repro.core.minibatch import CapacityPlan, build_minibatch
from repro.core.rng import DependentRNG
from repro.core.samplers import make_sampler
from repro.data import rmat_graph

graph = rmat_graph(scale=12, edge_factor=8, max_degree=32, seed=0)

# 1) the RNG mechanism: correlation across steps
ids = jnp.arange(4096)
r0 = DependentRNG(7, 64, 0).vertex_uniform(ids)
for step in (1, 16, 48, 64):
    r = DependentRNG(7, 64, step).vertex_uniform(ids)
    c = float(jnp.corrcoef(r0, r)[0, 1])
    print(f"corr(r_t @ step 0, step {step:3d}) = {c:+.3f}")

# 2) LRU miss rate vs kappa
sampler = make_sampler("labor0", fanout=5)
caps = CapacityPlan.geometric(128, 2, 5, graph.num_vertices)
for kappa in (1, 16, 64, None):
    cache = LRUCache(capacity=graph.num_vertices // 2)
    rng_np = np.random.default_rng(0)
    for step in range(20):
        seeds = rng_np.choice(graph.num_vertices, size=128, replace=False)
        rng = DependentRNG(base_seed=11, kappa=kappa, step=step)
        mb = build_minibatch(graph, sampler, jnp.asarray(seeds, jnp.int32),
                             rng, 2, caps)
        cache.access_batch(np.asarray(mb.input_ids))
    print(f"kappa={str(kappa):>4s}  LRU miss rate = {cache.miss_rate:.3f}")
