"""Dependent minibatching demo: cache locality vs kappa (paper §4.2).

    PYTHONPATH=src python examples/dependent_minibatching.py

Shows the smoothed-RNG mechanism (A.7) directly — per-vertex variates
drift slowly within a kappa window — and the resulting LRU miss-rate
drop for vertex-embedding fetches, streaming plans through the
``MinibatchEngine`` with double-buffered prefetch.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, LRUCache, MinibatchEngine
from repro.core.rng import DependentRNG
from repro.data import rmat_graph

graph = rmat_graph(scale=12, edge_factor=8, max_degree=32, seed=0)

# 1) the RNG mechanism: correlation across steps
ids = jnp.arange(4096)
r0 = DependentRNG(7, 64, 0).vertex_uniform(ids)
for step in (1, 16, 48, 64):
    r = DependentRNG(7, 64, step).vertex_uniform(ids)
    c = float(jnp.corrcoef(r0, r)[0, 1])
    print(f"corr(r_t @ step 0, step {step:3d}) = {c:+.3f}")

# 2) LRU miss rate vs kappa: one engine per dependency window
for kappa in (1, 16, 64, None):
    eng = MinibatchEngine.from_config(
        graph,
        EngineConfig(
            mode="independent", num_pes=1, local_batch=128, num_layers=2,
            sampler="labor0", fanout=5, schedule="smoothed", kappa=kappa,
            seed=11,
        ),
    )
    cache = LRUCache(capacity=graph.num_vertices // 2)
    # stream() drives eng.plan_at(step) under the hood: seed draw, RNG
    # schedule and sampling run as one device-resident program per step
    for item in eng.stream(num_steps=20):
        cache.access_batch(np.asarray(item.plan.input_ids).ravel())
    print(f"kappa={str(kappa):>4s}  LRU miss rate = {cache.miss_rate:.3f}")
