"""Serve a small LM with batched requests (decode path demo).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b]

Instantiates the REDUCED variant of an assigned architecture, prefills
the whole prompt batch in ONE compiled call (``prefill_decode`` scans
the per-token decode step, so caches come out bit-identical to stepping
``serve_step`` over the prompt) and then greedy-decodes new tokens with
the KV/SSM cache ``serve_step`` — the same code path the decode dry-run
shapes lower at production size.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models.transformer import (
    init_decode_state,
    init_lm,
    prefill_decode,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S0 = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S0)), jnp.int32)

    max_len = S0 + args.new_tokens
    state = init_decode_state(cfg, B, max_len)
    if cfg.enc_dec:
        state["enc_out"] = jnp.zeros((B, cfg.enc_len, cfg.d_model))
    serve = jax.jit(make_serve_step(cfg))
    prefill = jax.jit(lambda p, st, t: prefill_decode(p, cfg, st, t))

    # prefill the whole prompt in one batched call (caches bit-identical
    # to stepping the decoder token by token — pinned by tier-1 tests)
    t0 = time.time()
    logits, state = prefill(params, state, prompts)
    # sample greedily for new tokens
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(args.new_tokens):
        out.append(np.asarray(tok)[:, 0])
        logits, state = serve(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, 1)
    total = B * (S0 + args.new_tokens)
    print(f"arch={cfg.name}  batch={B}  decoded {gen.shape[1]} tokens/seq")
    print(f"tokens: {gen[0][:12].tolist()} ...")
    print(f"{total / dt:.1f} tok/s on CPU (reduced config)")


if __name__ == "__main__":
    main()
