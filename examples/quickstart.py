"""Quickstart: cooperative vs independent minibatching in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic power-law graph, samples one minibatch both ways at
identical global batch size, and prints the work reduction (the paper's
core claim), then trains a GCN for a few cooperative steps.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CapacityPlan,
    CoopCapacityPlan,
    DependentRNG,
    SimExecutor,
    build_cooperative_minibatch,
    build_minibatch,
    plan_stats,
)
from repro.core.partition import hash_partition
from repro.core.samplers import make_sampler
from repro.data import rmat_graph
from repro.data.synthetic import SyntheticGraphDataset
from repro.models.gnn import GNNConfig
from repro.train.loop import TrainConfig, train_gnn

P, B_LOCAL, LAYERS, FANOUT = 4, 128, 3, 5

graph = rmat_graph(scale=12, edge_factor=8, max_degree=32, seed=0)
print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges}")

sampler = make_sampler("labor0", fanout=FANOUT)
rng = DependentRNG(base_seed=0, kappa=1, step=0)
IM = np.iinfo(np.int32).max

# --- independent: P PEs, each with its own batch of size B_LOCAL ---
caps_i = CapacityPlan.geometric(B_LOCAL, LAYERS, FANOUT, graph.num_vertices)
rng_np = np.random.default_rng(0)
indep_inputs = 0
for p in range(P):
    seeds = rng_np.choice(graph.num_vertices, size=B_LOCAL, replace=False)
    mb = build_minibatch(graph, sampler, jnp.asarray(seeds, jnp.int32), rng,
                         LAYERS, caps_i)
    indep_inputs += int(mb.num_inputs)

# --- cooperative: ONE global batch of size P*B_LOCAL, owner-partitioned ---
part = hash_partition(graph.num_vertices, P)
owner = np.asarray(part.owner)
seeds = np.full((P, B_LOCAL), IM, np.int32)
for p in range(P):
    own = np.nonzero(owner == p)[0]
    seeds[p] = rng_np.choice(own, size=B_LOCAL, replace=False)
caps_c = CoopCapacityPlan.geometric(B_LOCAL, LAYERS, FANOUT,
                                    graph.num_vertices, P)
mb_c = build_cooperative_minibatch(graph, sampler, part, jnp.asarray(seeds),
                                   rng, LAYERS, caps_c, SimExecutor(P))
stats = plan_stats(mb_c, SimExecutor(P))
coop_inputs = P * stats["inputs"]  # upper bound: max-per-PE * P

print(f"independent total feature rows fetched : {indep_inputs}")
print(f"cooperative total feature rows fetched : <= {coop_inputs} "
      f"({indep_inputs / coop_inputs:.2f}x saving)")

# --- train a few cooperative steps ---
ds = SyntheticGraphDataset(graph, feature_dim=32, num_classes=8, seed=0)
cfg = GNNConfig(model="gcn", num_layers=2, in_dim=32, hidden_dim=64,
                num_classes=8)
tc = TrainConfig(mode="cooperative", num_pes=2, local_batch=64, num_steps=20,
                 fanout=FANOUT, eval_every=0)
result = train_gnn(ds, cfg, tc)
print(f"cooperative training loss: {result.losses[0]:.3f} -> "
      f"{result.losses[-1]:.3f}")
