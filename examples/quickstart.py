"""Quickstart: cooperative vs independent minibatching in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic power-law graph, then samples one minibatch plan both
ways — through the SAME ``MinibatchEngine`` API, differing only in
``mode`` — at identical global batch size, and prints the feature-
loading work reduction (the paper's core claim).  Finally trains a GCN
for a few cooperative steps.
"""
from repro.core import EngineConfig, MinibatchEngine
from repro.data import rmat_graph
from repro.data.synthetic import SyntheticGraphDataset
from repro.models.gnn import GNNConfig
from repro.train.loop import TrainConfig, train_gnn

P, B_LOCAL, LAYERS, FANOUT = 4, 128, 3, 5

graph = rmat_graph(scale=12, edge_factor=8, max_degree=32, seed=0)
print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges}")

# ONE config; the minibatching mode is the only thing that changes.
cfg = EngineConfig(
    mode="independent", num_pes=P, local_batch=B_LOCAL, num_layers=LAYERS,
    sampler="labor0", fanout=FANOUT, seed=0,
)

# --- independent: P PEs, each with its own batch of size B_LOCAL ---
eng_i = MinibatchEngine.from_config(graph, cfg)
plan_i = eng_i.plan_at(0)  # seed draw + RNG + sampling, one jitted program
indep_inputs = int(plan_i.num_inputs)  # total rows fetched across all PEs

# --- cooperative: ONE global batch of size P*B_LOCAL, owner-partitioned ---
eng_c = MinibatchEngine.from_config(graph, cfg.with_mode("cooperative"))
plan_c = eng_c.plan_at(0)
coop_inputs = P * plan_c.stats()["inputs"]  # upper bound: max-per-PE * P

print(f"independent total feature rows fetched : {indep_inputs}")
print(f"cooperative total feature rows fetched : <= {coop_inputs} "
      f"({indep_inputs / coop_inputs:.2f}x saving)")

# --- train a few cooperative steps (same engine under the hood) ---
ds = SyntheticGraphDataset(graph, feature_dim=32, num_classes=8, seed=0)
gnn = GNNConfig(model="gcn", num_layers=2, in_dim=32, hidden_dim=64,
                num_classes=8)
tc = TrainConfig(mode="cooperative", num_pes=2, local_batch=64, num_steps=20,
                 fanout=FANOUT, eval_every=0)
result = train_gnn(ds, gnn, tc)
print(f"cooperative training loss: {result.losses[0]:.3f} -> "
      f"{result.losses[-1]:.3f}")
