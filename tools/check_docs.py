"""Validate the `path.py:Symbol` pointers the docs are built from.

docs/*.md and README.md anchor every architectural claim to code with
backticked pointers in two forms:

* ``src/repro/engine/shard.py:ShardRunner`` — the file must exist and
  the symbol must be a top-level function/class, a ``Class.method`` /
  ``Class.attr``, or a module-level constant in that file's AST;
* ``src/repro/core/partition.py`` (any backticked token containing a
  ``/`` and a known extension, or ending in ``/``) — the path must
  exist in the repo.

Tokens with spaces (shell commands) and bare filenames with no
directory component (generated artifacts like ``BENCH_*.json``) are
ignored. Exit is non-zero if any pointer is dead, so CI catches docs
rot the moment a symbol is renamed:

    python tools/check_docs.py            # docs/*.md + README.md
    python tools/check_docs.py docs/kernels.md
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BACKTICK = re.compile(r"`([^`\n]+)`")
SYMBOL_PTR = re.compile(r"^(?P<path>[\w./-]+\.py):(?P<sym>[A-Za-z_][\w.]*)$")
PATH_EXTS = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".sh")


def module_symbols(py_path: Path) -> set:
    """Top-level defs/classes/constants + Class.method / Class.attr."""
    tree = ast.parse(py_path.read_text())
    syms = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            syms.add(node.name)
        elif isinstance(node, ast.ClassDef):
            syms.add(node.name)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    syms.add(f"{node.name}.{item.name}")
                elif isinstance(item, ast.AnnAssign) and isinstance(
                    item.target, ast.Name
                ):
                    syms.add(f"{node.name}.{item.target.id}")
                elif isinstance(item, ast.Assign):
                    for t in item.targets:
                        if isinstance(t, ast.Name):
                            syms.add(f"{node.name}.{t.id}")
        elif isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ):
            syms.add(node.target.id)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    syms.add(t.id)
    return syms


def check_file(md_path: Path, symbol_cache: dict) -> list:
    """-> list of (line_no, token, reason) dead pointers."""
    dead = []
    for line_no, line in enumerate(md_path.read_text().splitlines(), 1):
        for token in BACKTICK.findall(line):
            if " " in token:
                continue  # shell command, prose
            m = SYMBOL_PTR.match(token)
            if m:
                target = REPO / m["path"]
                if not target.is_file():
                    dead.append((line_no, token, "file missing"))
                    continue
                if target not in symbol_cache:
                    symbol_cache[target] = module_symbols(target)
                if m["sym"] not in symbol_cache[target]:
                    dead.append((line_no, token, "symbol missing"))
            elif "/" in token and (
                token.endswith(PATH_EXTS) or token.endswith("/")
            ):
                if not (REPO / token).exists():
                    dead.append((line_no, token, "path missing"))
    return dead


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        targets = [Path(a).resolve() for a in argv]
    else:
        targets = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    missing = [t for t in targets if not t.is_file()]
    if missing:
        for t in missing:
            print(f"MISSING doc: {t}", file=sys.stderr)
        return 1

    symbol_cache, total_dead, total_ptrs = {}, 0, 0
    for md in targets:
        dead = check_file(md, symbol_cache)
        n_ptrs = sum(
            1
            for line in md.read_text().splitlines()
            for tok in BACKTICK.findall(line)
            if " " not in tok and (SYMBOL_PTR.match(tok) or "/" in tok)
        )
        total_ptrs += n_ptrs
        rel = md.relative_to(REPO) if md.is_relative_to(REPO) else md
        if dead:
            total_dead += len(dead)
            for line_no, token, reason in dead:
                print(f"DEAD {rel}:{line_no}: `{token}` ({reason})",
                      file=sys.stderr)
        else:
            print(f"ok   {rel}: {n_ptrs} pointers")
    if total_dead:
        print(f"{total_dead} dead pointer(s)", file=sys.stderr)
        return 1
    print(f"all {total_ptrs} pointers resolve across {len(targets)} file(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
